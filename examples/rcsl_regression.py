"""Paper reproduction: RCSL vs MOM-RCSL on linear & logistic regression
(Tables 3-6 of the paper), under Gaussian / omniscient / bit-flip /
label-flip Byzantine attacks.

  PYTHONPATH=src python examples/rcsl_regression.py [--reps 20] [--full]

With --full this matches the paper's 500-rep setting (slow on CPU).
Expected qualitative result (paper Tables 3-6): every ratio < 1, i.e.
VRMOM-aggregated RCSL beats MOM-RCSL, with the gap shrinking as the
Byzantine fraction grows.
"""
import argparse

from benchmarks import paper_tables as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=12)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    reps = 500 if args.full else args.reps

    print("== Linear regression (paper Tables 3-4) ==")
    print(f"{'setting':34s} {'RCSL':>8s} {'ratio(RCSL/MOM-RCSL)':>22s}")
    for name, rmse, ratio in T.tables34(reps=reps):
        if name.endswith("/rcsl"):
            print(f"{name:34s} {rmse:8.4f} {ratio:22.4f}")

    print("\n== Logistic regression, label-flip attack (Tables 5-6) ==")
    for name, rmse, ratio in T.tables56(reps=max(reps // 2, 4)):
        if name.endswith("/rcsl"):
            print(f"{name:34s} {rmse:8.4f} {ratio:22.4f}")


if __name__ == "__main__":
    main()
