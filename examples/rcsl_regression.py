"""Paper reproduction: RCSL vs MOM-RCSL on linear & logistic regression
(Tables 3-6 of the paper), under Gaussian / omniscient / bit-flip /
label-flip Byzantine attacks — now with the paper's headline normality
result: per-coordinate plug-in confidence intervals (repro.infer,
DESIGN.md §9) printed next to the point estimate, and an empirical
coverage table.

  PYTHONPATH=src python examples/rcsl_regression.py [--reps 20] [--full]

With --full this matches the paper's 500-rep setting (slow on CPU).
Expected qualitative result (paper Tables 3-6): every ratio < 1, i.e.
VRMOM-aggregated RCSL beats MOM-RCSL, with the gap shrinking as the
Byzantine fraction grows; CI coverage stays near the nominal level and
VRMOM intervals are narrower than MOM intervals.
"""
import argparse
import os
import sys

# Allow `python examples/rcsl_regression.py` to find the benchmarks/
# package (sys.path[0] is examples/, not the repo root).
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

import jax

from benchmarks import paper_tables as T
from repro.core import rcsl as R
from repro.infer import infer


def show_intervals(alpha=0.1, attack="gaussian", level=0.95):
    """One RCSL fit with sandwich CIs — the asymptotic-normality result
    (the paper's Theorem on inference) made tangible."""
    p = 8
    theta_star = R.paper_theta_star(p)
    prob = R.LinearRegressionProblem()
    kd, kr, ks = jax.random.split(jax.random.PRNGKey(0), 3)
    shards = R.make_shards(kd, N_per_machine=500, m_workers=100, p=p,
                           theta_star=theta_star, model="linear")
    theta_hat, _ = R.rcsl(prob, shards, kr, alpha=alpha, attack=attack,
                          rounds=6)
    res = infer(prob, shards, theta_hat, estimator="vrmom", level=level,
                alpha=alpha, attack=attack, key=ks)
    n_byz = int(alpha * 100)
    print(f"== Linear RCSL fit, {n_byz}/101 machines Byzantine "
          f"({attack}), {level:.0%} plug-in CIs ==")
    print(f"{'coord':>5s} {'theta*':>9s} {'theta_hat':>10s} "
          f"{'CI':>22s}  covered")
    for l in range(p):
        lo, hi = float(res.ci.lower[l]), float(res.ci.upper[l])
        star = float(theta_star[l])
        mark = "yes" if lo <= star <= hi else "NO"
        print(f"{l:5d} {star:9.4f} {float(theta_hat[l]):10.4f} "
              f"[{lo:9.4f}, {hi:9.4f}]  {mark}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=12)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    reps = 500 if args.full else args.reps

    show_intervals()

    print("\n== Linear regression (paper Tables 3-4) ==")
    print(f"{'setting':34s} {'RCSL':>8s} {'ratio(RCSL/MOM-RCSL)':>22s}")
    for name, rmse, ratio in T.tables34(reps=reps):
        if name.endswith("/rcsl"):
            print(f"{name:34s} {rmse:8.4f} {ratio:22.4f}")

    print("\n== Logistic regression, label-flip attack (Tables 5-6) ==")
    for name, rmse, ratio in T.tables56(reps=max(reps // 2, 4)):
        if name.endswith("/rcsl"):
            print(f"{name:34s} {rmse:8.4f} {ratio:22.4f}")

    print("\n== CI coverage (nominal 95%, repro.infer) ==")
    print(f"{'setting':34s} {'coverage':>9s} {'mean width':>11s}")
    for name, cov, width in T.table_coverage(reps=max(4 * reps, 48)):
        print(f"{name:34s} {cov:9.3f} {width:11.4f}")


if __name__ == "__main__":
    main()
