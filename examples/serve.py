"""Serving example: thin CLI over the ``repro.serve`` engine.

Runs a reduced variant of any assigned architecture on host devices,
prefills a batch of prompts and decodes continuations in one fused
scan dispatch. Compile time is reported separately from steady-state
throughput (the first call of each jitted program pays tracing + XLA
compilation; timing it together with decode used to overstate the
per-token cost by orders of magnitude).

All timings go through ``repro.obs`` (DESIGN.md §11) under the same
metric names ``benchmarks/serve.py`` records — ``serve.compile_s``,
``serve.ttft_s``, ``serve.decode_step_s`` — and ``--metrics-out FILE``
appends the registry snapshot as telemetry JSONL for
``scripts/metrics_dump.py``.

  PYTHONPATH=src python examples/serve.py --arch mixtral-8x7b --tokens 16
  PYTHONPATH=src python examples/serve.py --robust --attack signflip
  PYTHONPATH=src python examples/serve.py --scheduler --requests 6
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get as get_arch
from repro.obs import JsonlSink, MetricsRegistry
from repro.obs.metrics import now
from repro.serve import (GREEDY, Request, RobustDecodeConfig, Sampling,
                         Scheduler, ServeEngine)
from repro.models import model as M


def build_batch(cfg, batch, prompt_len):
    out = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab)}
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (batch, cfg.encoder.n_frames, cfg.d_model), jnp.float32)
    elif cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (batch, cfg.vision.n_patches, cfg.d_model), jnp.float32)
    return out


def run_batch(engine, cfg, args, sampling, reg):
    batch = build_batch(cfg, args.batch, args.prompt_len)

    # compile + first call — a gauge, not a histogram: one value per run
    with reg.timer("serve.compile_s", kind="gauge"):
        gen = jax.block_until_ready(engine.generate(batch, args.tokens,
                                                    sampling=sampling))
    t_cold = reg.gauges["serve.compile_s"]

    # TTFT: prefill + first sampled token (everything is warm now)
    t0 = now()
    jax.block_until_ready(engine.generate(batch, 1, sampling=sampling))
    ttft = now() - t0
    reg.observe("serve.ttft_s", ttft)

    t0 = now()
    gen = jax.block_until_ready(engine.generate(batch, args.tokens,
                                                sampling=sampling))
    t_warm = now() - t0
    tok_s = args.tokens * args.batch / max(t_warm, 1e-9)
    # steady-state per-token decode cost: the warm call minus its
    # prefill/first-token part, over the scanned tokens
    reg.observe("serve.decode_step_s",
                max(t_warm - ttft, 0.0) / max(args.tokens - 1, 1))

    print(f"{cfg.name}: {args.batch}x{args.prompt_len} prompt, "
          f"{args.tokens} new tokens/seq")
    print(f"  compile+first call: {t_cold:.2f}s   "
          f"steady-state: {t_warm:.3f}s ({tok_s:.1f} tok/s)   "
          f"ttft: {ttft * 1e3:.1f}ms")
    print("  generated ids[0]:", list(map(int, gen[0])))
    assert bool(jnp.all(gen >= 0)) and bool(jnp.all(gen < cfg.vocab))


def run_scheduler(engine, cfg, args, sampling, reg):
    sched = Scheduler(engine, decode_block=args.decode_block,
                      sampling=sampling)
    rs = np.random.RandomState(0)
    for i in range(args.requests):
        extras = None
        if cfg.family == "encdec":
            extras = {"frames": rs.randn(cfg.encoder.n_frames,
                                         cfg.d_model).astype(np.float32)}
        elif cfg.family == "vlm":
            extras = {"patches": rs.randn(cfg.vision.n_patches,
                                          cfg.d_model).astype(np.float32)}
        sched.submit(Request(
            tokens=rs.randint(0, cfg.vocab,
                              size=(args.prompt_len + 2 * i,)),
            max_new_tokens=args.tokens, extras=extras))
    t0 = now()
    done = sched.run()
    dt = now() - t0
    n_tok = sum(len(c.tokens) for c in done.values())
    print(f"{cfg.name}: {args.requests} requests through "
          f"{engine.n_slots} slots (block={args.decode_block}) in {dt:.2f}s "
          f"— {n_tok} tokens (incl. compile)")
    for uid in sorted(done):
        c = done[uid]
        print(f"  req {uid}: prompt {len(c.prompt)} -> {len(c.tokens)} "
              f"tokens ({c.finished_by})")
    # the scheduler recorded admit/retire counters + TTFT / decode-step
    # histograms into the engine's registry as it ran (DESIGN.md §11)
    snap = reg.snapshot()
    cnt = snap["counters"]
    h = reg.histograms.get("serve.decode_step_s")
    extra = (f"  decode_step p50={h.percentile(50) * 1e3:.2f}ms "
             f"p95={h.percentile(95) * 1e3:.2f}ms" if h and h.count else "")
    print(f"  obs: admitted={cnt.get('serve.admitted', 0):.0f} "
          f"retired={cnt.get('serve.retired', 0):.0f} "
          f"rejected={cnt.get('serve.rejected', 0):.0f} "
          f"tokens_out={cnt.get('serve.tokens_out', 0):.0f}\n" + extra)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--scheduler", action="store_true",
                    help="continuous-batching demo instead of one batch")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--decode-block", type=int, default=4)
    ap.add_argument("--robust", action="store_true",
                    help="replicated Byzantine-robust decode")
    ap.add_argument("--replicas", type=int, default=8)
    ap.add_argument("--aggregator", default="vrmom")
    ap.add_argument("--attack", default="none",
                    help="fault injection: none|signflip|gaussian|...")
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--attn-backend", default=None,
                    choices=("auto", "jnp", "flash"),
                    help="attention backend override (DESIGN.md §8)")
    ap.add_argument("--metrics-out", default=None,
                    help="append the obs registry snapshot to this "
                         "telemetry JSONL (obs.sinks wire format)")
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)

    sampling = GREEDY
    if args.top_k:
        # temperature 0 means "greedy" on the CLI; within top-k it
        # degenerates to plain top-k at temperature 1.
        sampling = Sampling("top_k", args.temperature or 1.0, args.top_k)
    elif args.temperature > 0:
        sampling = Sampling("temperature", args.temperature)

    robust = None
    if args.robust:
        robust = RobustDecodeConfig(m=args.replicas,
                                    estimator=args.aggregator,
                                    attack=args.attack, alpha=args.alpha)
        print(f"robust decode: m={args.replicas} {args.aggregator}, "
              f"attack={args.attack} alpha={args.alpha}")

    reg = MetricsRegistry()
    max_len = args.prompt_len + 2 * args.requests + args.tokens + 8
    engine = ServeEngine(cfg, params, max_len=max_len, n_slots=args.slots,
                         robust=robust, attn_backend=args.attn_backend,
                         obs=reg)
    if args.scheduler:
        run_scheduler(engine, cfg, args, sampling, reg)
    else:
        run_batch(engine, cfg, args, sampling, reg)
    if args.metrics_out:
        with JsonlSink(args.metrics_out) as sink:
            sink.write_registry(reg, source="examples.serve", arch=cfg.name,
                                robust=bool(robust),
                                mode="scheduler" if args.scheduler
                                else "batch")
        print(f"metrics appended to {args.metrics_out}")


if __name__ == "__main__":
    main()
