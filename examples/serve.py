"""Serving example: batched prefill + decode with a KV cache.

Runs a reduced variant of any assigned architecture on host devices,
prefills a batch of prompts and greedily decodes continuations.

  PYTHONPATH=src python examples/serve.py --arch mixtral-8x7b --tokens 16
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get as get_arch
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init(key, cfg)

    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.encoder.n_frames, cfg.d_model), jnp.float32)
    elif cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.vision.n_patches, cfg.d_model), jnp.float32)

    max_len = args.prompt_len + args.tokens + 8
    prefill = jax.jit(lambda p, b: M.prefill(p, cfg, b, cache_len=max_len))
    decode = jax.jit(lambda p, c, t: M.decode_step(p, cfg, c, t))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    print(f"{cfg.name}: prefilled {args.batch}x{args.prompt_len} in "
          f"{time.time()-t0:.2f}s")

    out = [tok]
    t0 = time.time()
    for _ in range(args.tokens - 1):
        logits, caches = decode(params, caches, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.stack(out, axis=1)
    print(f"decoded {args.tokens} tokens/seq in {dt:.2f}s "
          f"({args.tokens * args.batch / max(dt, 1e-9):.1f} tok/s)")
    print("generated ids[0]:", list(map(int, gen[0])))
    assert bool(jnp.all(gen >= 0)) and bool(jnp.all(gen < cfg.vocab))


if __name__ == "__main__":
    main()
