"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on 8 (host) devices with Byzantine workers, comparing
VRMOM aggregation against the vanilla mean.

  PYTHONPATH=src python examples/train_byzantine.py \
      [--steps 200] [--dmodel 512] [--layers 8] [--attack omniscient]

The script sets up its own 8 host devices; run it directly (not under a
process that already initialized jax).
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse
import dataclasses

import jax
import numpy as np

import repro.optim as O
from repro.configs import get as get_arch
from repro.data import lm_batch, shard_batch
from repro.dist import sharding as S
from repro.dist.faults import FaultPlan
from repro.models import model as M
from repro.obs import JsonlSink, MetricsRegistry
from repro.obs.metrics import now
from repro.train.step import make_train_step


def build_cfg(d_model, layers, vocab=8192):
    base = get_arch("qwen3-1.7b")
    return dataclasses.replace(
        base, name=f"qwen3-{d_model}d{layers}L", d_model=d_model,
        n_layers=layers, n_heads=8, n_kv_heads=4, d_head=d_model // 8,
        d_ff=4 * d_model, vocab=vocab, param_dtype="float32",
        compute_dtype="float32", attn_chunk=128, loss_chunk=256, remat=False)


def run(cfg, mesh, *, steps, aggregator, byz, attack, seq, batch, lr, log,
        reg=None, reduce_backend="rrs", dropout=0.0):
    """``reg``: optional obs.MetricsRegistry — builds the step with
    ``with_diag=True`` and records the per-worker suspicion diagnostics
    (alpha-hat, suspected count, pre/post gradient norms) plus step time
    and loss after each step. The diag aux rides the same jitted step —
    no extra dispatches.

    ``reduce_backend="consensus"``: aggregate through the decentralized
    consensus wire (DESIGN.md §13) instead of the coordinator RRS,
    optionally with ``dropout`` message loss injected each round; the
    consensus aux (rounds, quorum, dropped messages) lands in ``reg``.
    """
    with_diag = reg is not None
    consensus = reduce_backend == "consensus"
    kw = {}
    if consensus:
        kw["reduce_backend"] = "consensus"
        if dropout:
            kw["fault_plan"] = FaultPlan(dropout=dropout)
    setup = make_train_step(cfg, mesh, estimator=aggregator,
                            mode="stacked-rrs" if aggregator != "mean"
                            else "mean",
                            byzantine_frac=byz, attack=attack, lr=lr,
                            microbatch=1, with_diag=with_diag, **kw)
    opt = O.get(cfg.optimizer, lr=lr)
    params = M.init(jax.random.PRNGKey(0), cfg)
    params = jax.device_put(params, S.to_named(mesh, setup.params_specs))
    opt_state = jax.jit(opt.init)(params)
    step = jax.jit(setup.step_fn)
    # Adaptive estimators (auto_gm / vrmom_adaptive): the census/EMA
    # state is an explicit jit carry through the step (DESIGN.md §14).
    adaptive = setup.init_state is not None
    agg_state = setup.init_state() if adaptive else None
    losses = []
    t0 = now()
    for i in range(steps):
        b = shard_batch(lm_batch(cfg, i, batch, seq), mesh, setup.batch_axes)
        ts = now()
        if adaptive:
            out = step(params, opt_state, b, jax.random.PRNGKey(i),
                       agg_state)
        else:
            out = step(params, opt_state, b, jax.random.PRNGKey(i))
        params, opt_state, loss = out[:3]
        rest = list(out[3:])
        if adaptive:
            agg_state = rest.pop(0)
        caux = rest.pop(0) if consensus else None
        diag = rest.pop(0) if with_diag else None
        losses.append(float(loss))  # blocks: device work for step i done
        if caux is not None and reg is not None:
            reg.observe("consensus.rounds", float(caux.rounds_to_eps))
            reg.counter("dist.messages_dropped",
                        float(caux.messages_dropped))
            reg.gauge("dist.quorum", float(caux.quorum))
        if with_diag:
            reg.observe("train.step_s", now() - ts)
            reg.gauge("train.loss", losses[-1])
            reg.gauge("agg.alpha_hat", float(diag.alpha_hat))
            reg.gauge("agg.suspected_workers",
                      float(np.asarray(diag.suspected).sum()))
            reg.gauge("agg.grad_norm_pre",
                      float(np.asarray(diag.pre_norms).mean()))
            reg.gauge("agg.grad_norm_post", float(diag.post_norm))
            if adaptive:
                reg.gauge("agg.worker_weight_min",
                          float(np.asarray(agg_state.weights).min()))
        if i % log == 0 or i == steps - 1:
            diag_note = ""
            if with_diag:
                diag_note = (f" alpha_hat={reg.gauges['agg.alpha_hat']:.3f}"
                             f" suspected="
                             f"{reg.gauges['agg.suspected_workers']:.0f}")
            print(f"  [{aggregator:6s} byz={byz:.2f}] step {i:4d} "
                  f"loss {losses[-1]:.4f} ({(now()-t0)/(i+1):.2f}s/it)"
                  + diag_note)
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dmodel", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=8192,
                    help="vocab size; the consensus wire is O(n^2 * "
                         "params) per round, so CI smoke runs shrink "
                         "this")
    ap.add_argument("--seq", type=int, default=128)
    # 8 sequences per worker: median-based aggregation needs each
    # worker's mean gradient to concentrate (the paper's n >> 1 per
    # machine). At 2 seqs/worker the coordinate-wise median of 4 noisy
    # means is too attenuated to descend.
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--byzantine", type=float, default=0.4)
    # (0.4 of 3 non-master workers floors to 1 Byzantine on the default
    #  4x2 host mesh; the paper uses floor(alpha*m) the same way)
    ap.add_argument("--attack", default="omniscient")
    ap.add_argument("--estimator", default="vrmom",
                    help="robust-arm aggregator: vrmom, median, "
                         "trimmed_mean, or an adaptive one (auto_gm, "
                         "vrmom_adaptive — DESIGN.md §14)")
    ap.add_argument("--reduce-backend", default="rrs",
                    choices=("rrs", "consensus"),
                    help="gradient aggregation wire: coordinator RRS or "
                         "decentralized approximate consensus (§13)")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="per-round message-loss probability injected "
                         "into the consensus wire (consensus backend "
                         "only)")
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--metrics-out", default=None,
                    help="append the obs registry snapshot to this "
                         "telemetry JSONL (obs.sinks wire format)")
    args = ap.parse_args()

    n = len(jax.devices())
    consensus = args.reduce_backend == "consensus"
    if consensus:
        # Consensus validity needs n_workers > 5f: put every device on
        # the worker axis (8 > 5), and keep the Byzantine count at 1
        # (f = 1) — floor(0.15 * 7) = 1.
        mesh = jax.make_mesh((n, 1), ("data", "model"))
        if int(args.byzantine * (n - 1)) > 1:
            print(f"consensus backend: clamping --byzantine "
                  f"{args.byzantine} -> 0.15 (n={n} workers supports "
                  f"f=1)")
            args.byzantine = 0.15
    else:
        mesh = jax.make_mesh((max(n // 2, 1), min(2, n)), ("data", "model"))
    cfg = build_cfg(args.dmodel, args.layers, vocab=args.vocab)
    n_params = sum(x.size for x in jax.tree.leaves(M.abstract_init(cfg)))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params, mesh "
          f"{dict(mesh.shape)}, attack={args.attack}, "
          f"backend={args.reduce_backend}"
          + (f", dropout={args.dropout}" if args.dropout else ""))

    common = dict(steps=args.steps, attack=args.attack, seq=args.seq,
                  batch=args.batch, lr=args.lr, log=args.log_every,
                  reduce_backend=args.reduce_backend, dropout=args.dropout)
    reg = MetricsRegistry()
    est_name = args.estimator
    print(f"== clean baseline ({est_name}, no Byzantine) ==")
    l_clean = run(cfg, mesh, aggregator=est_name, byz=0.0, **common)
    print(f"== {est_name} under {args.byzantine:.0%} Byzantine "
          f"(with diagnostics) ==")
    l_vr = run(cfg, mesh, aggregator=est_name, byz=args.byzantine,
               reg=reg, **common)
    print(f"== mean under {args.byzantine:.0%} Byzantine ==")
    # The mean arm stays on the plain (non-consensus) reduce on purpose:
    # under the consensus wire even est="mean" gets f-trimmed per round,
    # which would blunt the divergence this contrast demonstrates.
    l_mean = run(cfg, mesh, aggregator="mean", byz=args.byzantine,
                 **{**common, "reduce_backend": "rrs", "dropout": 0.0})
    if args.metrics_out:
        with JsonlSink(args.metrics_out) as sink:
            sink.write_registry(reg, source="examples.train_byzantine",
                                arch=cfg.name, attack=args.attack,
                                byzantine=args.byzantine)
        print(f"metrics appended to {args.metrics_out}")

    print("\nfinal losses: clean-%s %.4f | byz-%s %.4f | byz-mean %s"
          % (est_name, l_clean[-1], est_name, l_vr[-1],
             f"{l_mean[-1]:.4f}" if np.isfinite(l_mean[-1]) else "diverged"))
    assert l_clean[-1] < l_clean[0], "clean robust training should progress"
    # Under attack the robust run is guaranteed *stable* (bounded near
    # its start — descent needs longer horizons than a demo run).
    assert l_vr[-1] < l_vr[0] + 0.5, \
        f"{est_name} should stay stable under attack"
    if args.attack in ("alie", "ipm", "mimic"):
        # Stealth/omniscient-adaptive attacks: the payload sits inside
        # (alie, mimic) or scales with (ipm) the honest statistics, so
        # the mean arm degrades by per-step bias rather than diverging —
        # only finiteness is guaranteed at demo scale.
        assert np.isfinite(l_mean[-1]), \
            f"mean should stay finite under {args.attack}"
    else:
        # Loud attacks (omniscient/signflip/gaussian): the mean run
        # must diverge away from the robust one.
        assert (not np.isfinite(l_mean[-1])) or l_mean[-1] > l_vr[-1] + 1.0, \
            "mean aggregation should diverge where the robust arm holds"


if __name__ == "__main__":
    main()
