"""Quickstart: the VRMOM estimator in 60 seconds.

Reproduces the headline claim of the paper (Theorem 1): VRMOM keeps the
Byzantine robustness of median-of-means while recovering most of the
statistical efficiency the median throws away (2/pi = 0.637 -> 3/pi =
0.955 asymptotically).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import attacks, vrmom as V


def main():
    m1, n, reps, K = 101, 1000, 2000, 10
    key = jax.random.PRNGKey(0)

    # --- efficiency, no Byzantine machines -------------------------------
    xbar = jax.random.normal(key, (reps, m1)) / jnp.sqrt(n)  # machine means
    est_mean = jnp.mean(xbar, axis=1)
    est_mom = jax.vmap(V.mom)(xbar)
    est_vr = jax.vmap(lambda x: V.vrmom(x, K=K))(xbar)
    v = lambda x: float(jnp.var(x) * m1 * n)
    print("asymptotic variance x N (theory: mean=1, MOM=pi/2=1.571, "
          f"VRMOM_K10={V.sigma_k_sq(K):.3f})")
    print(f"  mean : {v(est_mean):.3f}")
    print(f"  MOM  : {v(est_mom):.3f}")
    print(f"  VRMOM: {v(est_vr):.3f}   (efficiency "
          f"{v(est_mean)/v(est_vr):.2f} vs MOM {v(est_mean)/v(est_mom):.2f})")

    # --- robustness: 20% Byzantine machines ------------------------------
    mask = attacks.byzantine_mask(m1, 0.2)
    xbad = jax.vmap(lambda x, k: attacks.gaussian(k, x, mask))(
        xbar, jax.random.split(jax.random.PRNGKey(1), reps))
    for name, fn in [("mean", lambda x: jnp.mean(x)),
                     ("MOM", V.mom),
                     ("VRMOM", lambda x: V.vrmom(x, K=K))]:
        est = jax.vmap(fn)(xbad)
        rmse = float(jnp.sqrt(jnp.mean(est**2)))
        print(f"  20% Byzantine, {name:5s}: RMSE {rmse:.5f}")

    # --- the unified Estimator layer (DESIGN.md §7) -----------------------
    # One spec drives every subsystem (dist RRS, serving, training);
    # backend="auto" runs the fused Pallas kernel (interpret on CPU).
    from repro.core import Estimator
    x = 3.0 + jax.random.normal(jax.random.PRNGKey(2), (33, 4096))
    out = Estimator(method="vrmom", K=10).apply(x)
    ref = jax.vmap(lambda c: V.vrmom(c, K=10), in_axes=1)(x)
    print(f"fused Estimator max|err| vs jnp estimator: "
          f"{float(jnp.max(jnp.abs(out - ref))):.2e}")

    print("\nWhere next:")
    print("  examples/rcsl_regression.py  - Algorithm 1 + plug-in CIs "
          "(the paper's normality result)")
    print("  examples/train_byzantine.py  - robust training on the model zoo")
    print("  examples/serve.py            - robust replicated decoding")
    print("  README.md                    - subsystem map and results; "
          "DESIGN.md for the why")


if __name__ == "__main__":
    main()
