"""Faithful reproductions of the paper's simulation tables.

Table 1: effect of K on VRMOM RMSE          (Section 4.1.1)
Table 2: VRMOM vs MOM RMSE + ratio          (Section 4.1.2)
Tables 3-4: RCSL vs MOM-RCSL, linear model, 3 attacks (Section 4.2.1)
Tables 5-6: RCSL vs MOM-RCSL, logistic, class (im)balance (Section 4.2.2)
Coverage table: plug-in CI coverage/width  (repro.infer, DESIGN.md §9)

Paper settings: N = 1000 x (100+1), n=1000, m=100 workers, p in {1,30},
K=10, 500 reps. ``reps`` is reduced by default for CPU runtime; pass
--full to ``examples/rcsl_regression.py`` for the paper's 500. Every
table function threads the size parameters (``n``, ``m_workers``,
``p``) so ``tests/test_paper_tables.py`` can smoke the exact table
code at toy sizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attacks as atk
from repro.core import rcsl as R
from repro.core import vrmom as V
from repro.infer import coverage_run


def _mean_vec(p):
    if p == 1:
        return jnp.ones((1,)) / jnp.sqrt(1.0)
    return R.paper_theta_star(p)


def _simulate_mean_estimation(key, p, m_workers, n, alpha, K, estimator):
    """One rep of Section 4.1: returns estimate error vector [p]."""
    mu = _mean_vec(p)
    k1, k2, k3 = jax.random.split(key, 3)
    raw0 = mu[None, :] + jax.random.normal(k1, (n, p))  # master's raw data
    xbar0 = jnp.mean(raw0, axis=0, keepdims=True)
    xbars = mu[None, :] + jax.random.normal(k2, (m_workers, p)) / jnp.sqrt(n)
    xbar = jnp.concatenate([xbar0, xbars], axis=0)  # [m+1, p]
    mask = atk.byzantine_mask(m_workers + 1, alpha)
    xbar = atk.gaussian(k3, xbar, mask)  # N(0, 200 I) (paper 4.1)
    if estimator == "vrmom":
        est = V.vrmom(xbar, K=K, scale="master", master_samples=raw0)
    elif estimator == "mom":
        est = V.mom(xbar)
    else:
        est = jnp.mean(xbar, axis=0)
    return est - mu


def _rmse_mean_est(p, alpha, K, estimator, reps, seed=0, m_workers=100,
                   n=1000):
    keys = jax.random.split(jax.random.PRNGKey(seed), reps)
    f = functools.partial(_simulate_mean_estimation, p=p, m_workers=m_workers,
                          n=n, alpha=alpha, K=K, estimator=estimator)
    errs = jax.lax.map(lambda k: f(k), keys, batch_size=50)
    per_rep = jnp.sqrt(jnp.mean(errs**2, axis=-1))
    return float(jnp.mean(per_rep)), float(jnp.std(per_rep))


def table1(reps=100, m_workers=100, n=1000, dims=(1, 30)):
    """name,us_per_call,derived rows: RMSE(VRMOM) for K grid x alpha grid."""
    rows = []
    for p in dims:
        for K in (10, 20, 50, 100):
            for alpha in (0.0, 0.05, 0.1, 0.15):
                rmse, sd = _rmse_mean_est(p, alpha, K, "vrmom", reps,
                                          m_workers=m_workers, n=n)
                rows.append((f"table1/p{p}/K{K}/a{alpha}", rmse, sd))
    return rows


def table2(reps=200, m_workers=100, n=1000, dims=(1, 30)):
    rows = []
    for p in dims:
        for alpha in (0.0, 0.05, 0.1, 0.15):
            rv, _ = _rmse_mean_est(p, alpha, 10, "vrmom", reps,
                                   m_workers=m_workers, n=n)
            rm, _ = _rmse_mean_est(p, alpha, 10, "mom", reps,
                                   m_workers=m_workers, n=n)
            rows.append((f"table2/p{p}/a{alpha}/vrmom", rv, rv / rm))
            rows.append((f"table2/p{p}/a{alpha}/mom", rm, 1.0))
    return rows


def _rcsl_rmse(model, attack, alpha, aggregator, reps, mu_x=0.0, seed=0,
               labelflip=False, p=30, m_workers=100, n=1000):
    theta = R.paper_theta_star(p)
    prob = (R.LinearRegressionProblem() if model == "linear"
            else R.LogisticRegressionProblem())

    def one(key):
        kd, kr = jax.random.split(key)
        shards = R.make_shards(kd, N_per_machine=n, m_workers=m_workers, p=p,
                               theta_star=theta, model=model, mu_x=mu_x)
        est, _ = R.rcsl(prob, shards, kr, alpha=alpha, attack=attack,
                        aggregator=aggregator, rounds=6, labelflip=labelflip)
        return jnp.sqrt(jnp.mean((est - theta) ** 2))

    keys = jax.random.split(jax.random.PRNGKey(seed), reps)
    vals = jax.lax.map(one, keys, batch_size=4)
    return float(jnp.mean(vals)), float(jnp.std(vals))


def tables34(reps=20, p=30, m_workers=100, n=1000):
    """Linear model, attacks x alpha, RCSL (VRMOM) vs MOM-RCSL."""
    kw = dict(p=p, m_workers=m_workers, n=n)
    rows = []
    r_v, _ = _rcsl_rmse("linear", "none", 0.0, "vrmom", reps, **kw)
    r_m, _ = _rcsl_rmse("linear", "none", 0.0, "median", reps, **kw)
    rows.append(("table3/none/a0/rcsl", r_v, r_v / r_m))
    rows.append(("table3/none/a0/mom-rcsl", r_m, 1.0))
    for attack in ("gaussian", "omniscient", "bitflip"):
        for alpha in (0.05, 0.1, 0.15):
            r_v, _ = _rcsl_rmse("linear", attack, alpha, "vrmom", reps, **kw)
            r_m, _ = _rcsl_rmse("linear", attack, alpha, "median", reps, **kw)
            rows.append((f"table3/{attack}/a{alpha}/rcsl", r_v, r_v / r_m))
            rows.append((f"table3/{attack}/a{alpha}/mom-rcsl", r_m, 1.0))
    return rows


def tables56(reps=10, p=30, m_workers=100, n=1000):
    """Logistic model, label-flip Byzantine gradients, mu_x in {0, 0.5}."""
    kw = dict(p=p, m_workers=m_workers, n=n)
    rows = []
    for mu_x in (0.0, 0.5):
        for alpha in (0.0, 0.05, 0.1, 0.15):
            r_v, _ = _rcsl_rmse("logistic", "none", alpha, "vrmom", reps,
                                mu_x=mu_x, labelflip=True, **kw)
            r_m, _ = _rcsl_rmse("logistic", "none", alpha, "median", reps,
                                mu_x=mu_x, labelflip=True, **kw)
            rows.append((f"table5/mu{mu_x}/a{alpha}/rcsl", r_v, r_v / r_m))
            rows.append((f"table5/mu{mu_x}/a{alpha}/mom-rcsl", r_m, 1.0))
    return rows


def table_coverage(reps=100, p=5, m_workers=100, n=200, level=0.95,
                   alphas=(0.0, 0.1), attack="gaussian"):
    """Plug-in CI coverage/width (repro.infer): the paper's normality
    result in table form. Rows: (name, empirical coverage, mean width)
    for VRMOM-RCSL vs MOM-RCSL on the linear model."""
    rows = []
    for alpha in alphas:
        for agg in ("vrmom", "median"):
            cell = coverage_run(
                model="linear", attack="none" if alpha == 0.0 else attack,
                alpha=alpha, estimator=agg, reps=reps, N_per_machine=n,
                m_workers=m_workers, p=p, rounds=6, level=level,
                batch_size=min(reps, 12))
            s = cell.summary()
            name = "rcsl" if agg == "vrmom" else "mom-rcsl"
            rows.append((f"coverage/{attack}/a{alpha}/{name}",
                         s["coverage"], s["mean_width"]))
    return rows
