"""Decode-attention micro-benchmark: fused kernel vs the jnp ``mha``.

Times the two execution backends of the serving decode hot loop
(DESIGN.md §8) at serving shapes — a single query per row over a KV
cache of T in {256, 1k, 4k} at the assigned archs' 4:1 GQA ratio — on
whatever backend this host has (the Pallas kernel runs in interpret
mode off-TPU: wide-tile config, correctness- and trend-representative).
The jnp row is the chunked ``mha`` exactly as the models run it
(per-row ``kv_len``, f32 scores); the flash row is
``kernels/decode_attention`` through the same jit.

Emits ``BENCH_attn.json``:

    {"B": 8, "H": 32, "Hkv": 8, "dh": 128,
     "us": {"T256": {"jnp": ..., "flash": ...}, ...},
     "speedup_vs_jnp": {"T256": ..., ...}}

  PYTHONPATH=src python -m benchmarks.attn [--batch 8] [--seqs 256,1024,4096]
      [--out BENCH_attn.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention
from repro.models.attention import mha


def _time(fn, *args, iters=5):
    """Best-of-``iters`` wall time after one warm-up (compile) call."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # us


def bench_decode(B=8, H=32, Hkv=8, dh=128, seqs=(256, 1024, 4096), iters=5,
                 out=None):
    rows, us_table = [], {}
    f_jnp = jax.jit(lambda q, k, v, l: mha(q, k, v, causal=False, window=None,
                                           chunk=1, kv_len=l))
    f_flash = jax.jit(lambda q, k, v, l: decode_attention(q, k, v, kv_len=l))
    for T in seqs:
        ks = jax.random.split(jax.random.PRNGKey(T), 3)
        q = jax.random.normal(ks[0], (B, 1, H, dh))
        k = jax.random.normal(ks[1], (B, T, Hkv, dh))
        v = jax.random.normal(ks[2], (B, T, Hkv, dh))
        # per-row lengths: the slot-serving signature (rows at different
        # fill levels), not the easier scalar special case
        lens = jnp.linspace(T // 2, T, B).astype(jnp.int32)
        err = float(jnp.max(jnp.abs(f_jnp(q, k, v, lens)
                                    - f_flash(q, k, v, lens))))
        us = {"jnp": _time(f_jnp, q, k, v, lens, iters=iters),
              "flash": _time(f_flash, q, k, v, lens, iters=iters)}
        us_table[f"T{T}"] = us
        for backend, t in us.items():
            rows.append((f"attn/decode/{backend}/b{B}xT{T}", t,
                         err if backend == "flash" else 0.0))
    if out:
        result = {
            "B": B, "H": H, "Hkv": Hkv, "dh": dh,
            "backend": jax.default_backend(),
            "us": us_table,
            "speedup_vs_jnp": {
                key: t["jnp"] / t["flash"] for key, t in us_table.items()},
        }
        with open(out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"# wrote {out}", file=sys.stderr)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--heads", type=int, default=32)
    ap.add_argument("--kv-heads", type=int, default=8,
                    help="GQA 4:1 by default (llama/starcoder class)")
    ap.add_argument("--head-dim", type=int, default=128)
    ap.add_argument("--seqs", default="256,1024,4096")
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--out", default="BENCH_attn.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    rows = bench_decode(B=args.batch, H=args.heads, Hkv=args.kv_heads,
                        dh=args.head_dim,
                        seqs=[int(s) for s in args.seqs.split(",")],
                        iters=args.iters, out=args.out)
    for row in rows:
        print(f"{row[0]},{row[1]:.6g},{row[2]:.6g}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
