"""Regime matrix: adaptive adversaries vs adaptive aggregation
(DESIGN.md §14).

Drives the attack x estimator x alpha grid through three production
wires and commits the result as ``BENCH_regimes.json``:

* **coverage** — the Monte-Carlo CI harness (``repro.infer.coverage``).
  Fixed arms model the analyst who assumes a clean fleet
  (``assumed_alpha=0.0``: no contamination inflation); adaptive arms
  plug in the *census-estimated* ``alpha_hat``
  (``repro.core.adaptive.estimate_alpha``) — nobody is told the true
  alpha. The stealth attacks (alie/ipm) are exactly the regimes where
  the fixed arms' uninflated CIs lose coverage while the census keeps
  the adaptive arms honest.
* **serve** — the m=8 replicated greedy-decode tail
  (``repro.serve.robust.robust_sample``): fraction of served tokens
  differing from the honest decode.
* **train** — the sharded Byzantine train step on a reduced qwen3
  model: loss stability under attack, with the adaptive arms threading
  their ``AdaptiveState`` carry.

The ``acceptance`` block is the committed tentpole claim: at alie or
ipm with alpha=0.2 BOTH fixed arms (vrmom, median) fail the coverage
gate (< 0.9) while BOTH adaptive arms (vrmom_adaptive, auto_gm) pass
it, and the fault-free adaptive estimators are bit-identical to their
fixed baselines.

  PYTHONPATH=src python -m benchmarks.regimes [--smoke] [--reps 96]
      [--out BENCH_regimes.json] [--no-mesh]

Importable without jax at module top: ``scripts/check_docs.py`` reads
the grid constants below to verify the DESIGN.md §14 regime table.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

# The regime grid (single source of truth for the DESIGN.md §14 table).
ATTACKS = ("gaussian", "signflip", "wrong_value", "alie", "ipm", "mimic")
ESTIMATOR_CELLS = ("median", "vrmom", "vrmom_adaptive", "trimmed_mean",
                   "auto_gm", "mean")
ALPHAS = (0.0, 0.1, 0.2)

FIXED_ARMS = ("vrmom", "median")         # acceptance: these fail the gate
ADAPTIVE_ARMS = ("vrmom_adaptive", "auto_gm")  # ... while these pass it
SERVE_ALPHA = 0.25
LEVEL = 0.95
COVERAGE_GATE = 0.90
K = 10
TRAIN_ATTACKS = ("ipm", "wrong_value")
TRAIN_ARMS = ("vrmom", "auto_gm", "mean")


def _estimator(name, K_=K, backend=None):
    from repro.core.estimator import Estimator

    kw = {"backend": backend} if backend else {}
    if name == "trimmed_mean":
        # beta must cover the worst grid alpha; the default 0.1 would
        # trim less than the contamination at alpha=0.2.
        return Estimator(method="trimmed_mean", beta=0.25, **kw)
    if name in ("vrmom", "vrmom_adaptive"):
        return Estimator(method=name, K=K_, **kw)
    return Estimator(method=name, **kw)


def _census_alpha_hat(attack, alpha, m_workers):
    """The adaptive arms' assumed contamination: census an attacked
    stack (the duplicate/loudness structure is attack-determined, not
    data-determined), exactly 0.0 for the clean regime."""
    import jax

    from repro.core import adaptive as AD
    from repro.core import attacks as A

    if alpha == 0.0 or attack == "none":
        return 0.0
    v = jax.random.normal(jax.random.PRNGKey(0), (m_workers + 1, 64)) + 1.0
    mask = A.byzantine_mask(m_workers + 1, alpha)
    v_att = A.REGISTRY[attack](jax.random.PRNGKey(1), v, mask)
    return float(AD.estimate_alpha(v_att, axis=0))


def run_coverage_wire(attacks, alphas, arms, reps, mesh=None, *,
                      m_workers=100, verbose=True):
    from repro.infer.coverage import coverage_run

    rows = {}
    cells = [("none", 0.0, arm) for arm in arms if 0.0 in alphas]
    cells += [(attack, alpha, arm) for attack in attacks
              for alpha in alphas if alpha > 0.0 for arm in arms]
    for attack, alpha, arm in cells:
        assumed = (_census_alpha_hat(attack, alpha, m_workers)
                   if arm in ADAPTIVE_ARMS else 0.0)
        cell_reps = reps
        if mesh is not None:
            w = int(mesh.shape["data"])
            cell_reps = max(w, cell_reps - cell_reps % w)
        t0 = time.perf_counter()
        cell = coverage_run(
            model="linear", attack=attack, alpha=alpha,
            # jnp backend: the coverage scan's remainder batch can be
            # zero-length, which the interpret-mode pallas kernel rejects
            # (and rcsl's own string coercion already pins jnp here).
            estimator=_estimator(arm, backend="jnp"),
            reps=cell_reps, N_per_machine=100,
            m_workers=m_workers, p=5, rounds=4, level=LEVEL, batch_size=12,
            mesh=mesh, assumed_alpha=assumed)
        s = cell.summary()
        s["assumed_alpha"] = round(assumed, 4)
        s["seconds"] = round(time.perf_counter() - t0, 2)
        name = f"coverage/{attack}/a{alpha}/{arm}"
        rows[name] = s
        if verbose:
            print(f"{name:42s} coverage={s['coverage']:.3f} "
                  f"width={s['mean_width']:.4f} assumed={assumed:.3f} "
                  f"({s['seconds']:.1f}s)", flush=True)
    return rows


def run_serve_wire(attacks, arms, verbose=True):
    """m=8 replica greedy decode: honest replicas are bit-identical, so
    a robust arm must serve the exact honest tokens under every attack
    at alpha=0.25."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.serve import RobustDecodeConfig, Sampling
    from repro.serve import robust as Ro

    B, V, m = 16, 128, 8
    honest = jax.random.normal(jax.random.PRNGKey(3), (B, V))
    logits_r = jnp.broadcast_to(honest[None], (m, B, V))
    want = np.asarray(jnp.argmax(honest, axis=-1))
    sc = Sampling(method="greedy")
    rows = {}
    for attack in attacks:
        for arm in arms:
            rcfg = RobustDecodeConfig(m=m, estimator=_estimator(arm, K_=8),
                                      attack=attack, alpha=SERVE_ALPHA)
            tok = np.asarray(Ro.robust_sample(
                logits_r, rcfg, jax.random.PRNGKey(7),
                jax.random.PRNGKey(0), sc))
            corr = float((tok != want).mean())
            name = f"serve/{attack}/a{SERVE_ALPHA}/{arm}"
            rows[name] = {"token_corruption": corr, "tokens": int(B)}
            if verbose:
                print(f"{name:42s} token_corruption={corr:.3f}", flush=True)
    return rows


def run_train_wire(attacks, arms, steps, verbose=True):
    """Reduced-model Byzantine descent: robust arms must stay stable
    where the mean degrades; adaptive arms thread their state carry."""
    import jax
    import numpy as np

    import repro.optim as O
    from repro.configs import get as get_arch
    from repro.data import lm_batch, shard_batch
    from repro.dist import sharding as S
    from repro.models import model as M
    from repro.train.step import make_train_step

    n = len(jax.devices())
    mesh = jax.make_mesh((max(n // 2, 1), min(2, n)), ("data", "model"))
    cfg = get_arch("qwen3-1.7b").reduced()
    rows = {}
    for attack in attacks:
        for arm in arms:
            t0 = time.perf_counter()
            setup = make_train_step(
                cfg, mesh, estimator=_estimator(arm),
                mode="mean" if arm == "mean" else "stacked-rrs",
                byzantine_frac=0.4, attack=attack, lr=1e-2, microbatch=1)
            adaptive = setup.init_state is not None
            state = setup.init_state() if adaptive else None
            opt = O.get(cfg.optimizer, lr=1e-2)
            params = M.init(jax.random.PRNGKey(0), cfg)
            params = jax.device_put(params,
                                    S.to_named(mesh, setup.params_specs))
            opt_state = jax.jit(opt.init)(params)
            step = jax.jit(setup.step_fn)
            losses = []
            for i in range(steps):
                b = shard_batch(lm_batch(cfg, i, 8, 32), mesh,
                                setup.batch_axes)
                if adaptive:
                    out = step(params, opt_state, b, jax.random.PRNGKey(i),
                               state)
                    params, opt_state, loss, state = out[:4]
                else:
                    out = step(params, opt_state, b, jax.random.PRNGKey(i))
                    params, opt_state, loss = out[:3]
                losses.append(float(loss))
            finite = bool(np.isfinite(losses[-1]))
            row = {
                "loss_first": losses[0], "loss_last": losses[-1],
                "finite": finite,
                "stable": finite and losses[-1] < losses[0] + 0.5,
                "seconds": round(time.perf_counter() - t0, 2),
            }
            if adaptive:
                row["alpha_hat"] = float(state.alpha_hat)
                row["worker_weight_min"] = float(state.weights.min())
            name = f"train/{attack}/a0.4/{arm}"
            rows[name] = row
            if verbose:
                print(f"{name:42s} loss {losses[0]:.3f}->{losses[-1]:.3f} "
                      f"stable={row['stable']} ({row['seconds']:.1f}s)",
                      flush=True)
    return rows


def bit_identity_record():
    """The zero-cost-adaptivity acceptance half: on honest data the
    adaptive estimators are bit-identical to their fixed baselines and
    the census is exactly silent."""
    import jax
    import numpy as np

    from repro.core import adaptive as AD
    # reprolint: disable=RL001 oracle: honest bit-identity compares auto_gm against raw weiszfeld below the Estimator layer
    from repro.core import aggregators as AG
    from repro.core.vrmom import vrmom

    v = jax.random.normal(jax.random.PRNGKey(5), (41, 40)) + 1.0
    gm = np.array_equal(np.asarray(AD.auto_gm(v, axis=0)),
                        np.asarray(AG.geometric_median(v, axis=0)))
    vr = np.array_equal(np.asarray(AD.vrmom_adaptive(v, K=K, axis=0)),
                        np.asarray(vrmom(v, K=K, axis=0)))
    return {
        "auto_gm_eq_geometric_median": bool(gm),
        "vrmom_adaptive_eq_vrmom": bool(vr),
        "honest_alpha_hat_zero":
            float(AD.estimate_alpha(v, axis=0)) == 0.0,
    }


def acceptance(rows, identity):
    """>= 1 stealth regime at alpha=0.2 where BOTH fixed arms fail the
    coverage gate and BOTH adaptive arms pass it, plus exact honest-
    regime bit identity."""
    regimes = {}
    for attack in ("alie", "ipm"):
        cov = {arm: rows.get(f"coverage/{attack}/a0.2/{arm}", {})
               .get("coverage") for arm in FIXED_ARMS + ADAPTIVE_ARMS}
        if any(c is None for c in cov.values()):
            continue
        regimes[attack] = {
            "coverage": cov,
            "fixed_fail": all(cov[a] < COVERAGE_GATE for a in FIXED_ARMS),
            "adaptive_pass": all(cov[a] >= COVERAGE_GATE
                                 for a in ADAPTIVE_ARMS),
        }
    gate = any(r["fixed_fail"] and r["adaptive_pass"]
               for r in regimes.values())
    ident = all(identity.values())
    return {
        "criterion": "at alie or ipm (alpha=0.2) fixed arms "
                     f"{FIXED_ARMS} have coverage < {COVERAGE_GATE} while "
                     f"adaptive arms {ADAPTIVE_ARMS} reach >= "
                     f"{COVERAGE_GATE}; fault-free adaptive estimators "
                     "bit-identical to fixed baselines",
        "regimes": regimes,
        "bit_identity": identity,
        "pass": bool(gate and ident),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reps", type=int, default=96,
                    help="replications per coverage cell")
    ap.add_argument("--steps", type=int, default=6,
                    help="train-wire steps per cell")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI: alpha=0.2 only, stealth "
                         "attacks, 16 reps, one train cell")
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--no-mesh", action="store_true",
                    help="ignore local devices, run single-device")
    args = ap.parse_args(argv)

    import jax

    mesh = None
    n_dev = len(jax.devices())
    if not args.no_mesh and n_dev > 1:
        mesh = jax.make_mesh((n_dev,), ("data",))
        print(f"sharding coverage replications over {n_dev} devices")

    if args.smoke:
        attacks, alphas, reps = ("alie", "ipm"), (0.0, 0.2), 16
        train_attacks, train_arms = ("ipm",), ("auto_gm", "mean")
        serve_attacks = ATTACKS
    else:
        attacks, alphas, reps = ATTACKS, ALPHAS, args.reps
        train_attacks, train_arms = TRAIN_ATTACKS, TRAIN_ARMS
        serve_attacks = ATTACKS

    t0 = time.perf_counter()
    rows = {}
    rows.update(run_coverage_wire(attacks, alphas, ESTIMATOR_CELLS, reps,
                                  mesh=mesh))
    rows.update(run_serve_wire(serve_attacks, ESTIMATOR_CELLS))
    rows.update(run_train_wire(train_attacks, train_arms, args.steps))
    identity = bit_identity_record()
    total_s = time.perf_counter() - t0

    out = {
        "settings": {
            "level": LEVEL, "reps": reps, "m_workers": 100, "p": 5,
            "K": K, "serve_alpha": SERVE_ALPHA,
            "coverage_gate": COVERAGE_GATE, "devices": n_dev,
            "smoke": bool(args.smoke),
            "total_seconds": round(total_s, 1),
        },
        "rows": rows,
        "acceptance": acceptance(rows, identity),
    }
    acc = out["acceptance"]
    print(f"acceptance: {'PASS' if acc['pass'] else 'FAIL'} "
          f"(bit_identity={identity})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.out}")
    return out


if __name__ == "__main__":
    main()
