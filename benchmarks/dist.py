"""Consensus-vs-RRS backend comparison + fault degradation (DESIGN.md §13).

Two experiments, one committed artifact (``BENCH_dist.json``):

1. **Backend comparison** on an 8-worker host mesh: wall time per
   jitted aggregation call and analytic wire bytes per worker for the
   centralized RRS backend (reduce-scatter + all-gather: ~2*C*4*(W-1)/W
   bytes) against the decentralized consensus backend (p_end rounds of
   all-to-all broadcast: rounds*(W-1)*C*4 bytes). The decentralization
   premium is explicit: consensus buys no-coordinator fault tolerance
   with O(rounds * W) wire traffic, never for free.

2. **Degradation curve** (host emulation, n = 8, f = 1): for each
   attack in {alie, omniscient} at alpha = 0.125 with a persistent
   (pinned) adversary, sweep message dropout and record the consensus
   error against the same cell's zero-dropout decision, rounds-to-eps,
   and the quorum gauge. This is the committed graceful-degradation
   evidence: error grows smoothly with loss rate and the quorum gauge
   reports the shrinking reception set — no cliffs, no NaNs.

  PYTHONPATH=src python -m benchmarks.dist [--smoke] [--out BENCH_dist.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# 8 host devices for the mesh comparison; must precede the jax import.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

if __package__ in (None, ""):
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import attacks as A
from repro.dist import robust_reduce as RR
from repro.dist.consensus import ConsensusConfig, aggregate_stacked_consensus, \
    consensus_aggregate
from repro.dist.faults import FaultPlan

N_WORKERS = 8
DROPOUTS = (0.0, 0.05, 0.1, 0.2, 0.3, 0.5)
ATTACKS = ("alie", "omniscient")
N_BYZ = 1      # 1 Byzantine row out of 8 (alpha = 0.125) -> f = 1
ALPHA = N_BYZ / N_WORKERS


def _timed(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def backend_comparison(C=1 << 16, iters=20):
    """Jitted wall time + analytic bytes for both backends, same wire."""
    mesh = jax.make_mesh((N_WORKERS, 1), ("data", "model"))
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (N_WORKERS, C))}
    gp = {"w": jax.device_put(g["w"],
                              NamedSharding(mesh, P("data", None)))}
    cfg = ConsensusConfig(f=1).validate(N_WORKERS)
    rounds = cfg.phases(None)

    rrs = jax.jit(lambda x: RR.aggregate_stacked_rrs(
        x, mesh, ("data",), "vrmom"))
    cons = jax.jit(lambda x: aggregate_stacked_consensus(
        x, mesh, ("data",), "vrmom", config=cfg))

    t_rrs = _timed(rrs, gp, iters=iters)
    t_cons = _timed(cons, gp, iters=iters)
    out_c, aux = cons(gp)
    out_r = rrs(gp)
    maxdiff = float(jnp.max(jnp.abs(out_c["w"] - out_r["w"])))

    bytes_rrs = 2 * C * 4 * (N_WORKERS - 1) / N_WORKERS
    bytes_cons = rounds * (N_WORKERS - 1) * C * 4
    return {
        "workers": N_WORKERS, "coords": C, "estimator": "vrmom",
        "rrs": {"seconds_per_call": t_rrs,
                "bytes_per_worker": bytes_rrs, "rounds": 1},
        "consensus": {"seconds_per_call": t_cons,
                      "bytes_per_worker": bytes_cons, "rounds": rounds,
                      "rounds_run": int(aux.rounds_run),
                      "rounds_to_eps": int(aux.rounds_to_eps)},
        "fault_free_maxdiff_vs_rrs": maxdiff,
        "wire_overhead_x": bytes_cons / bytes_rrs,
    }


def degradation_curve(C=512, seeds=8):
    """Emulated n=8 consensus under a pinned adversary x dropout sweep."""
    n = N_WORKERS
    cfg = ConsensusConfig(f=1, trim="midpoint").validate(n)
    # Direct mask: exactly N_BYZ of the n peers (byzantine_mask floors
    # alpha*(n-1), which would round 1/8 down to zero attackers).
    mask = jnp.arange(n) >= n - N_BYZ

    def cell(attack, dropout, seed):
        kv, ka, kc = jax.random.split(jax.random.PRNGKey(seed), 3)
        v = jax.random.normal(kv, (n, C))
        v_att = A.REGISTRY[attack](ka, v, mask)
        plan = FaultPlan(dropout=dropout).validate(n)
        got, aux = consensus_aggregate(v_att, "vrmom", config=cfg,
                                       plan=plan, key=kc, pin_mask=mask)
        ref, _ = consensus_aggregate(v_att, "vrmom", config=cfg,
                                     key=kc, pin_mask=mask)
        honest = jnp.mean(v[~mask], axis=0)
        return (float(jnp.max(jnp.abs(got - ref))),
                float(jnp.max(jnp.abs(got - honest))),
                int(aux.rounds_to_eps), float(aux.quorum),
                bool(aux.quorum_lost), int(aux.messages_dropped))

    rows = []
    for attack in ATTACKS:
        for dropout in DROPOUTS:
            res = [cell(attack, dropout, s) for s in range(seeds)]
            err, err_h, r2e, quorum, lost, dropped = zip(*res)
            rows.append({
                "attack": attack, "alpha": ALPHA, "dropout": dropout,
                "err_vs_no_dropout": float(np.mean(err)),
                "err_max": float(np.max(err)),
                "err_vs_honest_mean": float(np.mean(err_h)),
                "rounds_to_eps_mean": float(np.mean(r2e)),
                "quorum_mean": float(np.mean(quorum)),
                "quorum_lost_frac": float(np.mean(lost)),
                "messages_dropped_mean": float(np.mean(dropped)),
            })
            print(f"degrade {attack:10s} dropout={dropout:.2f} "
                  f"err={rows[-1]['err_vs_no_dropout']:.4f} "
                  f"err_honest={rows[-1]['err_vs_honest_mean']:.4f} "
                  f"rounds={rows[-1]['rounds_to_eps_mean']:.1f} "
                  f"quorum={rows[-1]['quorum_mean']:.3f}", flush=True)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny wire + few seeds for CI")
    ap.add_argument("--out", default=None, help="write JSON here")
    args = ap.parse_args(argv)

    C, iters, seeds = ((1 << 12, 3, 2) if args.smoke else (1 << 16, 20, 8))

    t0 = time.perf_counter()
    print("backend comparison (8-worker host mesh)...", flush=True)
    comp = backend_comparison(C=C, iters=iters)
    print(f"  rrs       {comp['rrs']['seconds_per_call']*1e3:8.2f} ms/call  "
          f"{comp['rrs']['bytes_per_worker']/1e6:.2f} MB/worker")
    print(f"  consensus {comp['consensus']['seconds_per_call']*1e3:8.2f} "
          f"ms/call  {comp['consensus']['bytes_per_worker']/1e6:.2f} "
          f"MB/worker  ({comp['consensus']['rounds']} rounds)")
    print(f"  fault-free maxdiff vs RRS: "
          f"{comp['fault_free_maxdiff_vs_rrs']:.2e}")

    curve = degradation_curve(C=min(C, 512), seeds=seeds)

    # Committed guarantees: fault-free equivalence is exact, and at 10%
    # loss the decision error stays small while quorum never collapses.
    at10 = [r for r in curve if r["dropout"] == 0.1]
    acceptance = {
        "fault_free_matches_rrs": comp["fault_free_maxdiff_vs_rrs"] == 0.0,
        "dropout10_err_max": max(r["err_max"] for r in at10),
        "dropout10_no_quorum_loss": all(r["quorum_lost_frac"] == 0.0
                                        for r in at10),
        "pass": (comp["fault_free_maxdiff_vs_rrs"] == 0.0
                 and all(r["quorum_lost_frac"] == 0.0 for r in at10)
                 and max(r["err_max"] for r in at10) < 2.0),
    }
    print(f"acceptance: {'PASS' if acceptance['pass'] else 'FAIL'} "
          f"(err@10%={acceptance['dropout10_err_max']:.3f})")

    out = {
        "settings": {"workers": N_WORKERS, "f": 1, "alpha": ALPHA,
                     "estimator": "vrmom", "coords_timing": C,
                     "smoke": bool(args.smoke),
                     "total_seconds": round(time.perf_counter() - t0, 1)},
        "backend_comparison": comp,
        "degradation": curve,
        "acceptance": acceptance,
    }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.out}")
    return out


if __name__ == "__main__":
    main()
