"""Micro-benchmarks: per-method x per-backend aggregation throughput.

Every row times one ``core.estimator.Estimator`` spec — the repo's
single aggregation dispatch site — so the numbers measure exactly what
the dist/serve/train paths run. Timing on CPU is indicative only (the
``pallas`` backend runs in interpret mode); the derived column reports
coords/us throughput, and for the kernel-parity rows the max abs error
vs the jnp reference.

``bench_backends`` emits ``BENCH_agg.json``:

    {"m": 8, "c": 65536, "us": {"vrmom": {"jnp": ..., "ref": ...,
     "pallas": ...}, ...}, "speedup_vs_jnp": {...}}

  PYTHONPATH=src python -m benchmarks.micro [--m 8] [--c 65536]
      [--out BENCH_agg.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import jax
import jax.numpy as jnp

from repro.core.estimator import (COORDINATEWISE_METHODS,
                                  WHOLE_VECTOR_METHODS, Estimator)
from repro.kernels import ref as kref
from repro.kernels.vrmom import vrmom_pallas

BACKENDS = ("jnp", "ref", "pallas")


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _estimators(m):
    """One representative spec per method, valid at worker count m."""
    for method in COORDINATEWISE_METHODS:
        if method == "mom":  # alias of median — skip the duplicate row
            continue
        yield Estimator(method=method, K=10, beta=max(0.1, 1.5 / m))
    for method in WHOLE_VECTOR_METHODS:
        yield Estimator(method=method, n_byzantine=max(m // 10, 1))


def bench_aggregators(m=33, c=65536):
    """Throughput of every method on its auto-resolved backend."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, c))
    rows = []
    for est in _estimators(m):
        fn = jax.jit(lambda x, e=est: e.apply(x))
        us = _time(fn, x)
        rows.append((f"micro/agg/{est.method}/{est.resolve_backend()}"
                     f"/m{m}xc{c}", us, c / max(us, 1e-9)))
    return rows


def bench_backends(m=8, c=65536, out=None):
    """Same coordinate-wise method across all three backends.

    The serving path's worker count (m=8 replicas) is the default: it is
    where the fused path's advantage matters (BENCH_serve.json).
    """
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, c))
    rows, us_table = [], {}
    for est in _estimators(m):
        if not est.coordinatewise:
            continue
        us_table[est.method] = {}
        for backend in BACKENDS:
            e = est._replace(backend=backend)
            fn = jax.jit(lambda x, e=e: e.apply(x))
            us = _time(fn, x)
            us_table[est.method][backend] = us
            rows.append((f"micro/backend/{est.method}/{backend}/m{m}xc{c}",
                         us, c / max(us, 1e-9)))
    if out:
        result = {
            "m": m, "c": c, "us": us_table,
            "speedup_vs_jnp": {
                meth: {b: t["jnp"] / t[b] for b in BACKENDS}
                for meth, t in us_table.items()},
        }
        with open(out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"# wrote {out}", file=sys.stderr)
    return rows


def bench_kernel(m=32, c=65536, K=10):
    """Pallas(interpret) vs jnp-oracle parity + indicative timing."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (m, c))
    oracle = jax.jit(lambda x: kref.ref_vrmom(x, K=K))
    us_ref = _time(oracle, x)
    # interpret-mode pallas: correctness-representative, not perf
    us_pal = _time(lambda x: vrmom_pallas(x, K=K, interpret=True), x, iters=2)
    err = float(jnp.max(jnp.abs(oracle(x)
                                - vrmom_pallas(x, K=K, interpret=True))))
    return [
        (f"micro/kernel/ref_vrmom/m{m}xc{c}", us_ref, 0.0),
        (f"micro/kernel/pallas_interpret/m{m}xc{c}", us_pal, err),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=8,
                    help="worker/replica count for the backend table")
    ap.add_argument("--c", type=int, default=65536)
    ap.add_argument("--out", default="BENCH_agg.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in bench_backends(m=args.m, c=args.c, out=args.out):
        print(f"{row[0]},{row[1]:.6g},{row[2]:.6g}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
