"""Micro-benchmarks: aggregator throughput + Pallas kernel vs oracle.

Timing on CPU is indicative only (the kernel path runs in interpret
mode); the derived column reports the relative accuracy / speed ratio.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import aggregators
from repro.kernels import ref as kref
from repro.kernels.vrmom import vrmom_pallas


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_aggregators(m=33, c=65536):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, c))
    rows = []
    for name in ("mean", "median", "vrmom", "trimmed_mean",
                 "geometric_median", "krum"):
        kw = {"n_byzantine": 2} if name == "krum" else {}
        fn = jax.jit(aggregators.get(name, **kw))
        us = _time(fn, x)
        rows.append((f"micro/agg/{name}/m{m}xc{c}", us, c / max(us, 1e-9)))
    return rows


def bench_kernel(m=32, c=65536, K=10):
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (m, c))
    oracle = jax.jit(lambda x: kref.ref_vrmom(x, K=K))
    us_ref = _time(oracle, x)
    # interpret-mode pallas: correctness-representative, not perf
    us_pal = _time(lambda x: vrmom_pallas(x, K=K, interpret=True), x, iters=2)
    err = float(jnp.max(jnp.abs(oracle(x)
                                - vrmom_pallas(x, K=K, interpret=True))))
    return [
        (f"micro/kernel/ref_vrmom/m{m}xc{c}", us_ref, 0.0),
        (f"micro/kernel/pallas_interpret/m{m}xc{c}", us_pal, err),
    ]
