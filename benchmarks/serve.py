"""Decode-throughput benchmark for the serve engine.

Measures steady-state (post-compile) greedy throughput with **prefill
and decode reported separately, per attention backend** (DESIGN.md §8):
end-to-end tok/s hides where a win comes from, and the attention-kernel
work of this repo moves the two phases differently (prefill is one
full-sequence forward; decode is the per-token loop the fused
decode-attention kernel targets). For each backend the decode loops all
start from the same prefilled caches:

* ``python_loop`` — per-step dispatch with a per-token host read, which
  is what a *serving* per-step loop is: every decoded token must reach
  the host for EOS detection / streaming before the next admission
  decision. The scanned block decode removes this per-token round-trip
  (the scheduler syncs once per block).
* ``python_loop_async`` — the literal pre-engine ``examples/serve.py``
  loop (jitted step + ``jnp.argmax`` per token, tokens only read at the
  end), which lets XLA's async dispatch pipeline the steps and hides
  part of the per-step cost.
* ``scan`` — the engine's fused ``lax.scan`` block decode.

Attention-free archs (SSM) run the ``jnp`` row only — there is no
attention to dispatch. The robust m-replica overhead is measured on the
flash backend (kernel attention + kernel aggregation in one scan), at
its original workload (prompt 24, 16 tokens — ``--robust-prompt-len`` /
``--robust-tokens``); plain and robust reps are interleaved because the
ratio of two separately-timed loops absorbs host-load drift. Two
emulations are timed against the same plain engine: ``overhead_x`` is
the default shared-replica-compute engine (one forward feeds the wire
stack — deployment wall-clock, where the m workers run in parallel),
``overhead_x_replicated`` serializes every replica's forward (the
pre-sharing cost model, comparable with the committed history). The
bench asserts both emulations emit bit-identical greedy tokens.

Emits ``BENCH_serve.json``:

    {"backends": {"jnp": {"prefill_us": {...}, "decode_tok_s":
        {"python_loop": {...}, "python_loop_async": {...}, "scan":
        {...}}}, "flash": {...}},
     "speedup_scan_vs_loop_b4": ..., "speedup_flash_vs_jnp_decode_b4":
     ..., "latency": {"ttft_s": {"p50": ..., "p95": ..., "p99": ...},
     "decode_step_s": {"p50": ..., "p95": ..., "p99": ...}},
     "robust": {"m": 8, "aggregator": "vrmom", "attn_backend": "flash",
     "tok_s": ..., "overhead_x": ..., "tok_s_replicated": ...,
     "overhead_x_replicated": ..., "emulations_token_identical": true,
     "obs_overhead_x": ...,
     "obs_tokens_identical": true, "replica_disagreement": {...},
     "fusion": {"unfused_tok_s": ..., "fused_agg_tok_s": ...,
     "fused_agg_sampling_tok_s": ..., "quantized_kv_tok_s": ...}}}

The ``robust.fusion`` block attributes the robust-decode throughput to
each fusion tier (DESIGN.md §12): jnp aggregation with a host argmax
tail, the Pallas aggregation kernel alone, the fused
aggregation+sampling tail, and the fused tail over a bf16-quantized KV
cache — each engine runs the same pinned workload so a regression
bisects to one fusion.

The latency percentiles come from ``repro.obs`` histograms recorded
under the same metric names the example CLI emits (``serve.ttft_s`` /
``serve.decode_step_s``), and ``--metrics-out`` appends the raw
registry snapshots to a telemetry JSONL for ``scripts/metrics_dump.py``.

  PYTHONPATH=src python -m benchmarks.serve [--arch qwen3-1.7b]
      [--tokens 16] [--batches 1,4,8] [--out BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)


def _time_steady(fn, reps: int):
    """Best-of-``reps`` wall time after one warm-up (compile) call."""
    fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _time_ratio(fn_a, fn_b, reps: int):
    """Best-of times for two functions with *interleaved* reps.

    A ratio of two separately-timed loops absorbs any load drift between
    the loops straight into the ratio (the robust-overhead metric moved
    ±15% run-to-run measured back-to-back); interleaving exposes both
    functions to the same drift.
    """
    fn_a(), fn_b()
    best_a = best_b = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn_a()
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b",
                    help="reduced arch to serve (attention arch default: "
                         "the decode-attention kernel is the hot path "
                         "this benchmark watches)")
    ap.add_argument("--prompt-len", type=int, default=192,
                    help="long enough that decode attention is a real "
                         "term of the per-token cost (a 24-token cache "
                         "hides the attention backend entirely)")
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--batches", default="1,4,8")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--replicas", type=int, default=8)
    ap.add_argument("--aggregator", default="vrmom")
    ap.add_argument("--robust-prompt-len", type=int, default=24)
    ap.add_argument("--robust-tokens", type=int, default=16,
                    help="the robust-overhead metric keeps its original "
                         "workload (prompt 24, 16 tokens) so overhead_x "
                         "stays comparable across the committed history "
                         "of BENCH_serve.json")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--metrics-out", default=None,
                    help="append the obs registry snapshots to this "
                         "telemetry JSONL (obs.sinks wire format)")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get as get_arch
    from repro.models import model as M
    from repro.obs import JsonlSink, MetricsRegistry
    from repro.obs.metrics import now
    from repro.serve import RobustDecodeConfig, ServeEngine
    from repro.serve.engine import GREEDY

    cfg = get_arch(args.arch).reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.tokens + 8
    N = args.tokens
    batches = [int(b) for b in args.batches.split(",")]
    backends = ("jnp",) if cfg.attention_free else ("jnp", "flash")

    result = {"arch": cfg.name, "tokens": N, "prompt_len": args.prompt_len,
              "backends": {}}

    print("name,us_per_call,derived")
    for backend in backends:
        eng = ServeEngine(cfg, params, max_len=max_len,
                          attn_backend=backend)
        bcfg = eng.cfg
        decode = jax.jit(lambda p, c, t, _cfg=bcfg: M.decode_step(p, _cfg,
                                                                  c, t))
        rb = result["backends"][backend] = {
            "prefill_us": {},
            "decode_tok_s": {"python_loop": {}, "python_loop_async": {},
                             "scan": {}},
        }
        for B in batches:
            batch = {"tokens": jax.random.randint(
                jax.random.PRNGKey(1), (B, args.prompt_len), 0, cfg.vocab)}
            t_pre = _time_steady(
                lambda: jax.block_until_ready(eng.prefill(batch)), args.reps)
            logits0, caches0 = jax.block_until_ready(eng.prefill(batch))
            tok0 = jnp.argmax(logits0, axis=-1).astype(jnp.int32)

            def loop_stream():
                # per-step serving loop: token read back every step (EOS
                # / streaming gate the next admission decision on it).
                tok, caches, out = tok0, caches0, [np.asarray(tok0)]
                for _ in range(N - 1):
                    logits, caches = decode(params, caches, tok)
                    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                    out.append(np.asarray(tok))
                return np.stack(out, axis=1)

            def loop_async():
                # the literal pre-engine example loop: nothing read until
                # the end, so async dispatch pipelines the steps.
                tok, caches, out = tok0, caches0, [tok0]
                for _ in range(N - 1):
                    logits, caches = decode(params, caches, tok)
                    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                    out.append(tok)
                return np.asarray(jnp.stack(out, axis=1))

            loop_fn = eng._decode_loop_fn(N - 1, GREEDY, pool=False)

            def scan_loop():
                toks, _ = loop_fn(params, caches0, tok0,
                                  jax.random.PRNGKey(0))
                return np.concatenate(
                    [np.asarray(tok0)[:, None], np.asarray(toks).T], axis=1)

            t_loop = _time_steady(loop_stream, args.reps)
            t_async = _time_steady(loop_async, args.reps)
            t_scan = _time_steady(scan_loop, args.reps)
            rb["prefill_us"][f"b{B}"] = t_pre * 1e6
            # steady-state decode throughput: N - 1 scanned tokens
            # (token 0 comes from the prefill logits, timed above)
            rb["decode_tok_s"]["python_loop"][f"b{B}"] = B * (N - 1) / t_loop
            rb["decode_tok_s"]["python_loop_async"][f"b{B}"] = (
                B * (N - 1) / t_async)
            rb["decode_tok_s"]["scan"][f"b{B}"] = B * (N - 1) / t_scan
            print(f"serve_prefill_{backend}_b{B},{t_pre * 1e6:.6g},")
            print(f"serve_loop_{backend}_b{B},{t_loop * 1e6:.6g},"
                  f"{B * (N - 1) / t_loop:.6g}")
            print(f"serve_loop_async_{backend}_b{B},{t_async * 1e6:.6g},"
                  f"{B * (N - 1) / t_async:.6g}")
            print(f"serve_scan_{backend}_b{B},{t_scan * 1e6:.6g},"
                  f"{B * (N - 1) / t_scan:.6g}")
            sys.stdout.flush()

    b4 = "b4" if 4 in batches else f"b{batches[0]}"
    best = backends[-1]
    scan_b4 = result["backends"][best]["decode_tok_s"]["scan"][b4]
    result["speedup_scan_vs_loop_b4"] = (
        scan_b4 / result["backends"][best]["decode_tok_s"]["python_loop"][b4])
    result["speedup_scan_vs_async_loop_b4"] = (
        scan_b4
        / result["backends"][best]["decode_tok_s"]["python_loop_async"][b4])
    if "flash" in backends:  # attention-free archs have no flash row
        result["speedup_flash_vs_jnp_decode_b4"] = (
            scan_b4 / result["backends"]["jnp"]["decode_tok_s"]["scan"][b4])
        if 8 in batches:
            result["speedup_flash_vs_jnp_decode_b8"] = (
                result["backends"]["flash"]["decode_tok_s"]["scan"]["b8"]
                / result["backends"]["jnp"]["decode_tok_s"]["scan"]["b8"])

    # latency percentiles (DESIGN.md §11): TTFT (prefill + first token,
    # the generate(·, 1) path) and per-token decode-step time, recorded
    # into the SAME obs histograms/metric names examples/serve.py uses —
    # percentile fields in BENCH_serve.json come from obs.Histogram, so
    # the CLI and the benchmark are bit-compatible telemetry producers.
    reg = MetricsRegistry()
    PB = 4 if 4 in batches else batches[0]
    pbatch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (PB, args.prompt_len), 0, cfg.vocab)}
    lat_reps = max(args.reps * 4, 16)
    np.asarray(eng.generate(pbatch, 1))  # warm (prefill + first-token jits)
    for _ in range(lat_reps):
        t0 = now()
        np.asarray(eng.generate(pbatch, 1))
        reg.observe("serve.ttft_s", now() - t0)
    logits0, caches0 = jax.block_until_ready(eng.prefill(pbatch))
    tok0 = jnp.argmax(logits0, axis=-1).astype(jnp.int32)
    loop_fn = eng._decode_loop_fn(N - 1, GREEDY, pool=False)
    jax.block_until_ready(loop_fn(params, caches0, tok0,
                                  jax.random.PRNGKey(0))[0])
    for _ in range(lat_reps):
        t0 = now()
        jax.block_until_ready(loop_fn(params, caches0, tok0,
                                      jax.random.PRNGKey(0))[0])
        reg.observe("serve.decode_step_s", (now() - t0) / (N - 1))
    result["latency"] = {
        "backend": best, "batch": PB, "samples": lat_reps,
        "ttft_s": {f"p{q}": reg.histograms["serve.ttft_s"]
                   .percentile(q) for q in (50, 95, 99)},
        "decode_step_s": {f"p{q}":
                          reg.histograms["serve.decode_step_s"]
                          .percentile(q) for q in (50, 95, 99)},
    }
    print(f"serve_ttft_p50_{best}_b{PB},"
          f"{result['latency']['ttft_s']['p50'] * 1e6:.6g},")
    print(f"serve_decode_step_p50_{best}_b{PB},"
          f"{result['latency']['decode_step_s']['p50'] * 1e6:.6g},")

    # robust replicated decode overhead (full generate path, batch 4) on
    # the fused backend: kernel attention + kernel aggregation in-scan
    B, RN, RPL = 4, args.robust_tokens, args.robust_prompt_len
    # cache sized to the workload: every slack slot is scanned by decode
    # attention each step (the replicated emulation pays it at m times
    # the rows of the plain engine) — padding would inflate the ratios
    # with cost the pinned workload never incurs.
    rmax_len = RPL + RN
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (B, RPL), 0, cfg.vocab)}
    eng = ServeEngine(cfg, params, max_len=rmax_len, attn_backend=best)
    reng = ServeEngine(cfg, params, max_len=rmax_len, attn_backend=best,
                       robust=RobustDecodeConfig(m=args.replicas,
                                                 estimator=args.aggregator))
    # replicated-forward emulation: every replica's (bit-identical)
    # forward executed serially — the pre-share_replica_compute cost
    # model, kept for comparability with the committed overhead_x
    # history and as the honest number for a host that must really run
    # all m replicas itself.
    rreng = ServeEngine(cfg, params, max_len=rmax_len, attn_backend=best,
                        robust=RobustDecodeConfig(
                            m=args.replicas, estimator=args.aggregator,
                            share_replica_compute=False))
    # the two emulations must be token-identical (greedy) — the shared
    # path's equivalence claim, enforced where the numbers are made.
    t_shared = np.asarray(reng.generate(batch, RN))
    t_repl = np.asarray(rreng.generate(batch, RN))
    if not (t_shared == t_repl).all():
        raise AssertionError("shared-compute robust emulation diverged "
                             "from the replicated-forward emulation")
    t_plain, t_rob = _time_ratio(
        lambda: jax.block_until_ready(eng.generate(batch, RN)),
        lambda: jax.block_until_ready(reng.generate(batch, RN)),
        max(args.reps, 8))
    t_plain2, t_rep = _time_ratio(
        lambda: jax.block_until_ready(eng.generate(batch, RN)),
        lambda: jax.block_until_ready(rreng.generate(batch, RN)),
        max(args.reps, 8))
    result["robust"] = {
        "m": args.replicas, "aggregator": args.aggregator,
        "attn_backend": best, "tokens": RN, "prompt_len": RPL,
        "tok_s": B * RN / t_rob, "overhead_x": t_rob / t_plain,
        "tok_s_replicated": B * RN / t_rep,
        "overhead_x_replicated": t_rep / t_plain2,
        "emulations_token_identical": True,
    }
    print(f"serve_robust_m{args.replicas},{t_rob * 1e6:.6g},"
          f"{t_rob / t_plain:.6g}")
    print(f"serve_robust_replicated_m{args.replicas},{t_rep * 1e6:.6g},"
          f"{t_rep / t_plain2:.6g}")

    # per-fusion attribution (DESIGN.md §12): which fusion buys what.
    # Each tier is its own engine on the same pinned workload; tok/s per
    # tier gets its own field so regressions bisect to a single fusion.
    #   unfused            jnp aggregation + host-side argmax tail
    #   fused_agg          Pallas aggregation kernel, separate argmax
    #   fused_agg_sampling one kernel: aggregation + sampling epilogue
    #   quantized_kv       fused tail + bf16 KV cache (half the HBM
    #                      traffic through decode attention)
    from repro.core.estimator import Estimator

    tiers = {
        "unfused_tok_s": ServeEngine(
            cfg, params, max_len=rmax_len, attn_backend=best,
            robust=RobustDecodeConfig(
                m=args.replicas,
                estimator=Estimator(method=args.aggregator, backend="jnp"),
                fuse_tail=False)),
        "fused_agg_tok_s": ServeEngine(
            cfg, params, max_len=rmax_len, attn_backend=best,
            robust=RobustDecodeConfig(
                m=args.replicas, estimator=args.aggregator,
                fuse_tail=False)),
        "fused_agg_sampling_tok_s": reng,
        "quantized_kv_tok_s": ServeEngine(
            cfg, params, max_len=rmax_len, attn_backend=best,
            kv_dtype="bfloat16",
            robust=RobustDecodeConfig(m=args.replicas,
                                      estimator=args.aggregator)),
    }
    fusion = {}
    for name, e in tiers.items():
        t = _time_steady(
            lambda e=e: jax.block_until_ready(e.generate(batch, RN)),
            max(args.reps, 8))
        fusion[name] = B * RN / t
        print(f"serve_robust_{name[:-6]}_m{args.replicas},{t * 1e6:.6g},"
              f"{fusion[name]:.6g}")
    result["robust"]["fusion"] = fusion

    # telemetry overhead (acceptance gate: < 5%): the same robust
    # engine with an obs registry runs a distinct compiled loop whose
    # only extra work is the in-scan disagreement histogram aux + one
    # host drain per dispatch. Tokens must stay bit-identical.
    obs_reg = MetricsRegistry()
    oeng = ServeEngine(cfg, params, max_len=rmax_len, attn_backend=best,
                       robust=RobustDecodeConfig(m=args.replicas,
                                                 estimator=args.aggregator),
                       obs=obs_reg)
    t_off, t_on = _time_ratio(
        lambda: jax.block_until_ready(reng.generate(batch, RN)),
        lambda: jax.block_until_ready(oeng.generate(batch, RN)),
        max(args.reps, 8))
    toks_off = np.asarray(reng.generate(batch, RN))
    toks_on = np.asarray(oeng.generate(batch, RN))
    result["robust"]["obs_overhead_x"] = t_on / t_off
    result["robust"]["obs_tokens_identical"] = bool(
        np.array_equal(toks_off, toks_on))
    print(f"serve_robust_obs_m{args.replicas},{t_on * 1e6:.6g},"
          f"{t_on / t_off:.6g}")

    # live Byzantine signal: replica disagreement under a signflip
    # attack at alpha=0.25 — floor(0.25 * m) corrupted replicas out of
    # m should put the mean per-token disagreement rate near alpha.
    areg = MetricsRegistry()
    aeng = ServeEngine(cfg, params, max_len=rmax_len, attn_backend=best,
                       robust=RobustDecodeConfig(m=args.replicas,
                                                 estimator=args.aggregator,
                                                 attack="signflip",
                                                 alpha=0.25),
                       obs=areg)
    np.asarray(aeng.generate(batch, RN))
    hd = areg.histograms["serve.replica_disagreement"]
    result["robust"]["replica_disagreement"] = {
        "attack": "signflip", "alpha": 0.25,
        "mean": hd.mean, "count": hd.count,
    }
    print(f"serve_replica_disagreement_m{args.replicas},,{hd.mean:.6g}")

    if args.metrics_out:
        with JsonlSink(args.metrics_out) as sink:
            sink.write_registry(reg, source="benchmarks.serve",
                                section="latency", arch=cfg.name)
            sink.write_registry(obs_reg, source="benchmarks.serve",
                                section="robust", arch=cfg.name)
            sink.write_registry(areg, source="benchmarks.serve",
                                section="robust-attacked", arch=cfg.name)

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    flash_note = ""
    if "speedup_flash_vs_jnp_decode_b4" in result:
        flash_note = (f"flash vs jnp scanned decode = "
                      f"{result['speedup_flash_vs_jnp_decode_b4']:.2f}x, ")
    print(f"# wrote {args.out}: scan vs per-step loop at {b4} = "
          f"{result['speedup_scan_vs_loop_b4']:.2f}x, {flash_note}"
          f"robust overhead ({best}) = "
          f"{result['robust']['overhead_x']:.2f}x",
          file=sys.stderr)


if __name__ == "__main__":
    main()
