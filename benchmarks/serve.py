"""Decode-throughput benchmark for the serve engine.

Measures steady-state (post-compile) greedy *decode-loop* throughput —
prefill excluded, both loops start from the same prefilled caches — of
the fused ``lax.scan`` loop against the per-step Python loop it
replaced, plus the overhead of m-replica Byzantine-robust decoding over
plain decoding.

Two baselines are recorded, because the old loop's cost depends on
whether anyone looks at the tokens:

* ``python_loop`` — per-step dispatch with a per-token host read, which
  is what a *serving* per-step loop is: every decoded token must reach
  the host for EOS detection / streaming before the next admission
  decision. The scanned block decode is the thing that removes this
  per-token round-trip (the scheduler syncs once per block).
* ``python_loop_async`` — the literal pre-engine ``examples/serve.py``
  loop (jitted step + ``jnp.argmax`` per token, tokens only read at the
  end), which lets XLA's async dispatch pipeline the steps and hides
  part of the per-step cost.

Emits ``BENCH_serve.json``:

    {"tok_s": {"python_loop": {...}, "python_loop_async": {...},
               "scan": {...}},
     "speedup_scan_vs_loop_b4": ..., "speedup_scan_vs_async_loop_b4": ...,
     "robust": {"m": 8, "aggregator": "vrmom", "tok_s": ...,
                "overhead_x": ...}}

  PYTHONPATH=src python -m benchmarks.serve [--arch mamba2-2.7b]
      [--tokens 16] [--batches 1,4,8] [--out BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)


def _time_steady(fn, reps: int):
    """Best-of-``reps`` wall time after one warm-up (compile) call."""
    fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b",
                    help="reduced arch to serve (SSM default: O(1) decode "
                         "state makes it the natural serving arch)")
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batches", default="1,4,8")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--replicas", type=int, default=8)
    ap.add_argument("--aggregator", default="vrmom")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get as get_arch
    from repro.models import model as M
    from repro.serve import RobustDecodeConfig, ServeEngine
    from repro.serve.engine import GREEDY

    cfg = get_arch(args.arch).reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.tokens + 8
    N = args.tokens
    batches = [int(b) for b in args.batches.split(",")]

    result = {"arch": cfg.name, "tokens": N, "prompt_len": args.prompt_len,
              "tok_s": {"python_loop": {}, "python_loop_async": {},
                        "scan": {}}}
    eng = ServeEngine(cfg, params, max_len=max_len)
    decode = jax.jit(lambda p, c, t: M.decode_step(p, cfg, c, t))

    print("name,us_per_call,derived")
    for B in batches:
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (B, args.prompt_len), 0, cfg.vocab)}
        logits0, caches0 = jax.block_until_ready(eng.prefill(batch))
        tok0 = jnp.argmax(logits0, axis=-1).astype(jnp.int32)

        def loop_stream():
            # per-step serving loop: token read back every step (EOS /
            # streaming gate the next admission decision on it).
            tok, caches, out = tok0, caches0, [np.asarray(tok0)]
            for _ in range(N - 1):
                logits, caches = decode(params, caches, tok)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                out.append(np.asarray(tok))
            return np.stack(out, axis=1)

        def loop_async():
            # the literal pre-engine example loop: nothing read until
            # the end, so async dispatch pipelines the steps.
            tok, caches, out = tok0, caches0, [tok0]
            for _ in range(N - 1):
                logits, caches = decode(params, caches, tok)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                out.append(tok)
            return np.asarray(jnp.stack(out, axis=1))

        loop_fn = eng._decode_loop_fn(N - 1, GREEDY, pool=False)

        def scan_loop():
            toks, _ = loop_fn(params, caches0, tok0, jax.random.PRNGKey(0))
            return np.concatenate(
                [np.asarray(tok0)[:, None], np.asarray(toks).T], axis=1)

        t_loop = _time_steady(loop_stream, args.reps)
        t_async = _time_steady(loop_async, args.reps)
        t_scan = _time_steady(scan_loop, args.reps)
        result["tok_s"]["python_loop"][f"b{B}"] = B * N / t_loop
        result["tok_s"]["python_loop_async"][f"b{B}"] = B * N / t_async
        result["tok_s"]["scan"][f"b{B}"] = B * N / t_scan
        print(f"serve_loop_b{B},{t_loop * 1e6:.6g},{B * N / t_loop:.6g}")
        print(f"serve_loop_async_b{B},{t_async * 1e6:.6g},"
              f"{B * N / t_async:.6g}")
        print(f"serve_scan_b{B},{t_scan * 1e6:.6g},{B * N / t_scan:.6g}")
        sys.stdout.flush()

    b4 = "b4" if 4 in batches else f"b{batches[0]}"
    result["speedup_scan_vs_loop_b4"] = (
        result["tok_s"]["scan"][b4] / result["tok_s"]["python_loop"][b4])
    result["speedup_scan_vs_async_loop_b4"] = (
        result["tok_s"]["scan"][b4]
        / result["tok_s"]["python_loop_async"][b4])

    # robust replicated decode overhead (full generate path, batch 4)
    B = 4
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (B, args.prompt_len), 0, cfg.vocab)}
    reng = ServeEngine(cfg, params, max_len=max_len,
                       robust=RobustDecodeConfig(m=args.replicas,
                                                 estimator=args.aggregator))
    t_plain = _time_steady(
        lambda: jax.block_until_ready(eng.generate(batch, N)), args.reps)
    t_rob = _time_steady(
        lambda: jax.block_until_ready(reng.generate(batch, N)), args.reps)
    result["robust"] = {
        "m": args.replicas, "aggregator": args.aggregator,
        "tok_s": B * N / t_rob, "overhead_x": t_rob / t_plain,
    }
    print(f"serve_robust_m{args.replicas},{t_rob * 1e6:.6g},"
          f"{t_rob / t_plain:.6g}")

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"# wrote {args.out}: scan vs per-step loop at {b4} = "
          f"{result['speedup_scan_vs_loop_b4']:.2f}x "
          f"(vs async loop {result['speedup_scan_vs_async_loop_b4']:.2f}x), "
          f"robust overhead = {result['robust']['overhead_x']:.2f}x",
          file=sys.stderr)


if __name__ == "__main__":
    main()
