"""Benchmark harness. One function per paper table + micro benches.

Prints ``name,us_per_call,derived`` CSV rows (for the paper tables, the
us_per_call column carries the RMSE and derived carries the VRMOM/MOM
ratio or std).

  PYTHONPATH=src python -m benchmarks.run [--full] [--only table2,micro]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __package__ in (None, ""):
    # Invoked as a script (``python benchmarks/run.py``): relative imports
    # have no parent package, so register the repo root (for ``benchmarks``)
    # and ``src`` (for ``repro``) on sys.path explicitly.
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale reps (500); default is reduced")
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table2,table34,table56,micro")
    args = ap.parse_args()

    # absolute import works for both script mode (sys.path shim above)
    # and ``python -m benchmarks.run`` (repo root already importable)
    from benchmarks import micro, paper_tables as T

    sections = {
        "table1": lambda: T.table1(reps=500 if args.full else 60),
        "table2": lambda: T.table2(reps=500 if args.full else 120),
        "table34": lambda: T.tables34(reps=500 if args.full else 12),
        "table56": lambda: T.tables56(reps=500 if args.full else 8),
        "micro": lambda: (micro.bench_aggregators() + micro.bench_backends()
                          + micro.bench_kernel()),
    }
    only = set(args.only.split(",")) if args.only else set(sections)

    print("name,us_per_call,derived")
    for name, fn in sections.items():
        if name not in only:
            continue
        t0 = time.time()
        for row in fn():
            print(f"{row[0]},{row[1]:.6g},{row[2]:.6g}")
            sys.stdout.flush()
        print(f"# section {name} took {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
