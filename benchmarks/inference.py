"""Coverage/width tables for the plug-in inference layer (DESIGN.md §9).

Reproduces the statistical-guarantee side of the paper's Section 4: for
every (model, attack, Byzantine fraction, aggregator) cell, run a
fully-compiled Monte-Carlo coverage experiment
(``repro.infer.coverage_run`` — ``lax.map``-batched replications, no
per-rep Python dispatch; shard_map-sharded over the local device mesh
when one is available) and record empirical coverage of the nominal-95%
sandwich CIs, mean CI width, and point-estimate RMSE.

Emits ``BENCH_inference.json``:

    {"settings": {...},
     "rows": {"linear/gaussian/a0.1/vrmom": {"coverage": 0.96, ...}, ...},
     "acceptance": {"cell": "linear/gaussian/a0.1/vrmom",
                    "coverage": ..., "nominal": 0.95, "pass": true}}

The ``acceptance`` block is the repo's committed guarantee: empirical
coverage of VRMOM-RCSL on the linear model under the paper's Gaussian
attack at alpha = 0.1 stays within 3 points of the nominal 95%.

  PYTHONPATH=src python -m benchmarks.inference [--smoke] [--reps 200]
      [--out BENCH_inference.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import jax

from repro.infer import coverage_run

ATTACKS = ("gaussian", "signflip", "wrong_value")
ALPHAS = (0.05, 0.1, 0.2)
LEVEL = 0.95
# Logistic needs more per-machine data for the Newton solve's asymptotics.
N_PER_MACHINE = {"linear": 200, "logistic": 400}
ACCEPTANCE_CELL = "linear/gaussian/a0.1/vrmom"
ACCEPTANCE_TOL = 0.03


def _cells(models, attacks, alphas, aggregators):
    """The benchmark grid: one clean cell per (model, aggregator), then
    the full attack x alpha cross."""
    for model in models:
        for agg in aggregators:
            yield model, "none", 0.0, agg
            for attack in attacks:
                for alpha in alphas:
                    yield model, attack, alpha, agg


def run_grid(models, attacks, alphas, aggregators, reps, mesh=None,
             verbose=True):
    rows = {}
    for model, attack, alpha, agg in _cells(models, attacks, alphas,
                                            aggregators):
        # Logistic Newton solves make each rep ~2x a linear rep; the
        # coverage estimate tolerates fewer of them.
        cell_reps = reps if model == "linear" else max(reps // 2, 8)
        n = N_PER_MACHINE[model]
        if mesh is not None:
            w = int(mesh.shape["data"])
            cell_reps = max(w, cell_reps - cell_reps % w)
        t0 = time.perf_counter()
        cell = coverage_run(
            model=model, attack=attack, alpha=alpha, estimator=agg,
            reps=cell_reps, N_per_machine=n, m_workers=100, p=5, rounds=6,
            level=LEVEL, batch_size=12, mesh=mesh)
        s = cell.summary()
        s["seconds"] = round(time.perf_counter() - t0, 2)
        name = f"{model}/{attack}/a{alpha}/{agg}"
        rows[name] = s
        if verbose:
            print(f"{name:38s} coverage={s['coverage']:.3f} "
                  f"width={s['mean_width']:.4f} rmse={s['rmse']:.4f} "
                  f"({s['seconds']:.1f}s)", flush=True)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reps", type=int, default=200,
                    help="replications per linear cell (logistic uses half)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid + reps for CI (one attack, two alphas)")
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--no-mesh", action="store_true",
                    help="ignore local devices, run single-device")
    args = ap.parse_args(argv)

    mesh = None
    n_dev = len(jax.devices())
    if not args.no_mesh and n_dev > 1:
        mesh = jax.make_mesh((n_dev,), ("data",))
        print(f"sharding replications over {n_dev} local devices")

    if args.smoke:
        models, attacks, alphas = ("linear", "logistic"), ("gaussian",), (0.1,)
        aggregators, reps = ("vrmom",), min(args.reps, 24)
    else:
        models, attacks, alphas = ("linear", "logistic"), ATTACKS, ALPHAS
        aggregators, reps = ("vrmom", "median"), args.reps

    t0 = time.perf_counter()
    rows = run_grid(models, attacks, alphas, aggregators, reps, mesh=mesh)
    total_s = time.perf_counter() - t0

    out = {
        "settings": {
            "level": LEVEL, "reps_linear": reps, "m_workers": 100, "p": 5,
            "K": 10, "rounds": 6, "N_per_machine": N_PER_MACHINE,
            "devices": n_dev, "smoke": bool(args.smoke),
            "total_seconds": round(total_s, 1),
        },
        "rows": rows,
    }
    acc_row = rows.get(ACCEPTANCE_CELL)
    if acc_row is not None:
        out["acceptance"] = {
            "criterion": f"empirical coverage within {ACCEPTANCE_TOL:.0%} of "
                         f"nominal {LEVEL:.0%} for VRMOM-RCSL, linear model, "
                         f"gaussian attack, alpha=0.1",
            "cell": ACCEPTANCE_CELL,
            "coverage": acc_row["coverage"],
            "nominal": LEVEL,
            "pass": abs(acc_row["coverage"] - LEVEL) <= ACCEPTANCE_TOL,
        }
        print(f"acceptance [{ACCEPTANCE_CELL}]: "
              f"coverage={acc_row['coverage']:.3f} vs nominal {LEVEL} -> "
              f"{'PASS' if out['acceptance']['pass'] else 'FAIL'}")

    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.out}")
    return out


if __name__ == "__main__":
    main()
