"""whisper-medium [audio]: enc-dec, conv frontend stubbed [arXiv:2212.04356]."""
from .base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865, rope=False, tie_embeddings=True,
    encoder=EncoderConfig(n_layers=24, n_frames=1500),
    source="arXiv:2212.04356",
)
