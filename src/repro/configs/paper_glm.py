"""The paper's own simulation configurations (Section 4).

Not architectures — these parameterize the GLM experiments the paper
tables use. Kept here so benchmarks/examples share one source of truth.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GLMConfig:
    name: str
    model: str           # linear | logistic
    p: int = 30
    n_per_machine: int = 1000
    m_workers: int = 100
    K: int = 10
    toeplitz_rho: float = 0.5
    mu_x: float = 0.0
    reps: int = 500      # paper setting
    tol: float = 1e-4    # adaptive stopping (Section 4.2)


PAPER_LINREG = GLMConfig(name="paper-linreg", model="linear")
PAPER_LOGREG_BALANCED = GLMConfig(name="paper-logreg-balanced",
                                  model="logistic", mu_x=0.0)
PAPER_LOGREG_IMBALANCED = GLMConfig(name="paper-logreg-imbalanced",
                                    model="logistic", mu_x=0.5)
