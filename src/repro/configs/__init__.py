"""Assigned architecture configs. get(name) / list_archs()."""
from .base import INPUT_SHAPES, ArchConfig, EncoderConfig, InputShape, MoEConfig, SSMConfig, VisionStubConfig, input_specs
from .registry import ARCHS, get, list_archs
