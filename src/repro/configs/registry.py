"""Registry of the 10 assigned architectures (--arch <id>)."""
from . import (granite_moe_3b_a800m, llama3_405b, mamba2_2_7b, minitron_4b,
               mixtral_8x7b, phi_3_vision_4_2b, qwen3_1_7b, starcoder2_7b,
               whisper_medium, zamba2_7b)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (whisper_medium, qwen3_1_7b, starcoder2_7b, phi_3_vision_4_2b,
              zamba2_7b, granite_moe_3b_a800m, minitron_4b, mamba2_2_7b,
              mixtral_8x7b, llama3_405b)
}


def get(name: str):
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)
