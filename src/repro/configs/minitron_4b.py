"""minitron-4b [dense]: pruned nemotron, 256k vocab [arXiv:2407.14679]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_head=128,
    d_ff=9216, vocab=256000, tie_embeddings=False,
    source="arXiv:2407.14679",
)
