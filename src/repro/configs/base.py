"""Architecture + input-shape configuration.

Every assigned architecture is an ``ArchConfig``; the four assigned input
shapes are in ``INPUT_SHAPES``. ``input_specs(cfg, shape)`` returns
ShapeDtypeStruct stand-ins for every model input (weak-type-correct,
shardable, no device allocation) — used by the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..lint.hashguard import check_hashable_fields


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder. The conv/mel frontend is a STUB: inputs are
    precomputed frame embeddings [B, n_frames, d_model] (DESIGN.md §4)."""

    n_layers: int
    n_frames: int = 1500


@dataclasses.dataclass(frozen=True)
class VisionStubConfig:
    """VLM frontend stub: precomputed patch embeddings [B, n_patches, d]."""

    n_patches: int = 256


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads
    rope: bool = True
    rope_theta: float = 10000.0
    qk_norm: bool = False
    sliding_window: Optional[int] = None
    tie_embeddings: bool = True
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0  # zamba2: shared attn block every k layers
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionStubConfig] = None
    norm_eps: float = 1e-5
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    attn_chunk: int = 1024  # query-block size for chunked attention
    # attention execution backend (models/attn_backend.py, DESIGN.md §8):
    # "auto" | "jnp" (chunked mha reference) | "flash" (fused Pallas
    # kernels: full-seq flash + grouped-GQA decode)
    attn_backend: str = "auto"
    # KV-cache storage dtype (DESIGN.md §12): None -> compute_dtype;
    # "bfloat16" halves, "int8" quarters the per-slot cache footprint
    # (int8 carries per-(row, position) f32 scales beside the cache,
    # dequantized inside the decode-attention kernel's block loads).
    kv_dtype: Optional[str] = None
    loss_chunk: int = 1024  # sequence-chunked cross-entropy
    remat: bool = True
    remat_block: int = 1  # >1: two-level remat, store every Nth boundary
    optimizer: str = "adamw"  # llama3-405b overrides to adafactor
    source: str = ""  # citation

    def __post_init__(self):
        # ArchConfig flows into jit static args (step/serve closures key
        # their trace caches on it) — an unhashable field means a
        # retrace hazard or a TypeError at the jit boundary; fail at
        # construction, naming the field (reprolint RL004).
        check_hashable_fields(self)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can run long_500k natively (without the SWA variant)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant of the same family: <=2 layers, d_model<=256,
        <=4 experts, tiny vocab. Used by per-arch CPU smoke tests."""
        kw = {}
        if self.moe is not None:
            kw["moe"] = MoEConfig(
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                capacity_factor=self.moe.capacity_factor,
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=32, chunk=16
            )
        if self.encoder is not None:
            kw["encoder"] = EncoderConfig(n_layers=2, n_frames=12)
        if self.vision is not None:
            kw["vision"] = VisionStubConfig(n_patches=4)
        n_layers = min(self.n_layers, 4 if self.hybrid_attn_every else 2)
        kw["hybrid_attn_every"] = 2 if self.hybrid_attn_every else 0
        d_model = 128 if self.family != "ssm" else 64
        n_heads = min(self.n_heads, 4)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=min(self.n_kv_heads, max(1, n_heads // 2)),
            d_head=32,
            d_ff=256,
            vocab=512,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else None,
            param_dtype="float32",
            compute_dtype="float32",
            attn_chunk=16,
            loss_chunk=32,
            remat=False,
            **kw,
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: InputShape | str):
    """ShapeDtypeStruct stand-ins for the step function's data inputs.

    train:   tokens/labels [B, S] int32 (+ stubbed frontend embeddings)
    prefill: tokens [B, S]
    decode:  token [B] + positions handled by the cache (allocated inside
             the jitted step from the cache spec — see launch/dryrun.py).
    """
    if isinstance(shape, str):
        shape = INPUT_SHAPES[shape]
    B, S = shape.global_batch, shape.seq_len
    emb = jnp.dtype(cfg.compute_dtype)
    specs = {}
    if shape.kind == "train":
        if cfg.family == "encdec":
            specs["frames"] = _sds((B, cfg.encoder.n_frames, cfg.d_model), emb)
            specs["tokens"] = _sds((B, S), jnp.int32)
        elif cfg.family == "vlm":
            n_img = cfg.vision.n_patches
            specs["patches"] = _sds((B, n_img, cfg.d_model), emb)
            specs["tokens"] = _sds((B, S - n_img), jnp.int32)
        else:
            specs["tokens"] = _sds((B, S), jnp.int32)
    elif shape.kind == "prefill":
        if cfg.family == "encdec":
            specs["frames"] = _sds((B, cfg.encoder.n_frames, cfg.d_model), emb)
            specs["tokens"] = _sds((B, S), jnp.int32)
        elif cfg.family == "vlm":
            n_img = cfg.vision.n_patches
            specs["patches"] = _sds((B, n_img, cfg.d_model), emb)
            specs["tokens"] = _sds((B, S - n_img), jnp.int32)
        else:
            specs["tokens"] = _sds((B, S), jnp.int32)
    elif shape.kind == "decode":
        specs["token"] = _sds((B,), jnp.int32)
    else:
        raise ValueError(shape.kind)
    return specs
