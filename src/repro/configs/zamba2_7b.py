"""zamba2-7b [hybrid]: 81 Mamba2 blocks + shared attention block every 6
[arXiv:2411.15242]."""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_head=112,
    d_ff=14336, vocab=32000, hybrid_attn_every=6,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64),
    source="arXiv:2411.15242",
)
