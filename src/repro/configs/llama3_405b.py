"""llama3-405b [dense]: GQA kv=8, 128k vocab [arXiv:2407.21783].

Uses adafactor (f32 Adam moments exceed v5e HBM — DESIGN.md §5) and the
in-backward robust reduce (IB-RRS) aggregation mode at train time."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_head=128,
    d_ff=53248, vocab=128256, rope_theta=500_000.0, tie_embeddings=False,
    optimizer="adafactor", remat_block=7,
    source="arXiv:2407.21783",
)
