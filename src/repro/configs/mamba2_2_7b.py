"""mamba2-2.7b [ssm]: SSD, attention-free [arXiv:2405.21060]."""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280, rope=False,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64),
    source="arXiv:2405.21060",
)
