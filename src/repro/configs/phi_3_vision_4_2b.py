"""phi-3-vision-4.2b [vlm]: phi3-mini LM + stubbed CLIP patch embeddings
[hf:microsoft/Phi-3-vision-128k-instruct]."""
from .base import ArchConfig, VisionStubConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_head=96,
    d_ff=8192, vocab=32064, vision=VisionStubConfig(n_patches=256),
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)
