"""Backend-dispatched attention: one policy site, kernel-selected execution.

Mirror of the §7 Estimator pattern (DESIGN.md §8): model layers never
call a kernel directly — they call :func:`full_attention` /
:func:`decode_attention` here, and the backend carried on the model
config (``ArchConfig.attn_backend``) decides what runs:

* ``"jnp"``   — the chunked ``attention.mha``. Reference semantics, and
  the only backend that implements sliding-window masking and the
  TP head-padding layout.
* ``"flash"`` — the fused Pallas kernels: ``kernels/flash_attention``
  for full-sequence (train / prefill / encoder / cross) attention and
  ``kernels/decode_attention`` for single-query cached decode (GQA
  grouped in-kernel, per-row ``kv_len``). Off-TPU both run in interpret
  mode with wide tiles. Calls the kernels cannot express (sliding
  window, TP > 1 — both are ``mha``-only features) route to ``mha`` —
  that routing is *policy*, decided here per call signature, unlike the
  silent shape-dependent fallback the flash kernel used to hide inside
  its entry point.
* ``"auto"``  — ``flash`` for decode everywhere (the grouped kernel
  wins on TPU by construction and on host CPU via the wide interpret
  tile — ``BENCH_attn.json``); for full-sequence attention, ``flash``
  on TPU and ``mha`` on host (XLA's fused CPU matmuls beat interpret
  emulation at prefill shapes).

The full-sequence flash path is grad-safe: the kernel has no VJP rule,
so it is wrapped in a ``custom_vjp`` whose backward differentiates the
chunked ``mha`` reference (recompute-in-backward, exactly the remat
trade the chunked path already makes) — ``attn_backend="flash"`` is
valid under ``jax.grad``, not just at inference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

BACKENDS = ("auto", "jnp", "flash")

__all__ = ["BACKENDS", "resolve_backend", "full_attention",
           "decode_attention"]


def _tp() -> int:
    from ..dist import ctx

    return ctx.axis_size("model")


def resolve_backend(backend: str, *, decode: bool, window=None) -> str:
    """Resolve a config backend to the concrete one a call will run.

    ``window`` is the *positional* sliding-window constraint of the
    call (full-sequence attention only — decode masks by validity, so
    ring-cache decode has no positional window). Kernel-inexpressible
    signatures (window set, TP sharding active) resolve to ``jnp``.
    """
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown attn backend {backend!r}; known: {BACKENDS}")
    if window is not None or _tp() > 1:
        return "jnp"
    if backend == "auto":
        if decode:
            return "flash"
        return "flash" if jax.default_backend() == "tpu" else "jnp"
    return backend


@functools.lru_cache(maxsize=None)
def _flash_full(causal: bool, chunk: int):
    """Grad-safe full-sequence flash attention for a static signature.

    Forward: the fused kernel. Backward: VJP of the chunked ``mha``
    reference (same math — parity asserted in tests), recomputed from
    the saved q/k/v. Cached per static signature so the custom-vjp
    primitive is built once per config, keeping jit caches stable.
    """
    from ..kernels.flash_attention import flash_attention as _fa

    def _ref(q, k, v):
        from . import attention as A

        return A.mha(q, k, v, causal=causal, window=None, chunk=chunk)

    @jax.custom_vjp
    def f(q, k, v):
        return _fa(q, k, v, causal=causal)

    def fwd(q, k, v):
        return f(q, k, v), (q, k, v)

    def bwd(res, g):
        _, vjp = jax.vjp(_ref, *res)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def full_attention(q, k, v, cfg, *, causal, window, q_offset=0, kv_len=None):
    """Full-sequence attention [B,S,H,dh] x [B,T,Hkv,dh] -> [B,S,H,dh].

    The train / prefill / encoder / cross entry point. ``window`` /
    ``q_offset`` / ``kv_len`` follow ``attention.mha``; signatures the
    flash kernel can't express (window, offset/valid-length masks,
    TP > 1) resolve to the chunked jnp path.
    """
    from . import attention as A

    backend = getattr(cfg, "attn_backend", "auto")
    if q_offset != 0 or kv_len is not None:
        backend = "jnp"
    if resolve_backend(backend, decode=False, window=window) == "flash":
        return _flash_full(bool(causal), cfg.attn_chunk)(q, k, v)
    return A.mha(q, k, v, causal=causal, window=window, chunk=cfg.attn_chunk,
                 q_offset=q_offset, kv_len=kv_len)


def decode_attention(q, k, v, cfg, *, kv_len=None, k_scale=None,
                     v_scale=None):
    """Single-query cached attention [B,1,H,dh] x [B,T,Hkv,dh].

    The decode hot loop. ``kv_len``: scalar or per-row [B] valid cache
    length (slot serving); ring caches mask by validity only, so both
    cache geometries take the same kernel (DESIGN.md §6/§8).

    ``k_scale``/``v_scale``: per-(row, position) [B, T] f32 dequant
    scales of an int8 KV cache (DESIGN.md §12). The flash kernel fuses
    the dequant into its K/V block loads; the jnp reference dequantizes
    eagerly before ``mha``. bf16 caches carry no scales — both paths
    already upcast at read.
    """
    from . import attention as A

    backend = getattr(cfg, "attn_backend", "auto")
    if resolve_backend(backend, decode=True) == "flash":
        from ..kernels.decode_attention import decode_attention as _da

        return _da(q, k, v, kv_len=kv_len, k_scale=k_scale, v_scale=v_scale)
    if k_scale is not None:
        k = k.astype(jnp.float32) * k_scale[:, :, None, None]
        v = v.astype(jnp.float32) * v_scale[:, :, None, None]
        k = k.astype(q.dtype)
        v = v.astype(q.dtype)
    return A.mha(q, k, v, causal=False, window=None, chunk=1, kv_len=kv_len)
