"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD algorithm for train/prefill (within-chunk quadratic term +
inter-chunk recurrence over chunk states via lax.scan), exact one-step
recurrence for decode. Matches the naive recurrence oracle (tested in
tests/test_models.py::test_ssd_matches_naive_recurrence).

State per head: h in R^{P x N} (P = head_dim, N = d_state):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t (x) x_t
    y_t = C_t . h_t + D_skip * x_t
A is a per-head negative scalar (Mamba2 simplification).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm


class SSMCache(NamedTuple):
    h: jnp.ndarray        # [B, H, P, N]
    conv: jnp.ndarray     # [B, d_conv-1, d_inner]   (x stream)
    conv_bc: jnp.ndarray  # [B, d_conv-1, 2*G*N]     (B/C streams)
    pos: jnp.ndarray


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return d_inner, H, s.head_dim, s.d_state, s.n_groups


def mamba2_init(key, cfg):
    """Projections are SPLIT per stream (z / x / BC / dt) rather than one
    fused in_proj: slicing a model-sharded fused output forces per-layer
    all-gathers under GSPMD (EXPERIMENTS.md §Perf, zamba2 hillclimb).
    The depthwise conv splits exactly the same way (channel-separable)."""
    s = cfg.ssm
    d_inner, H, P, N, G = _dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    return {
        "in_proj_z": dense_init(ks[0], (cfg.d_model, d_inner), dt),
        "in_proj_x": dense_init(ks[1], (cfg.d_model, d_inner), dt),
        "in_proj_bc": dense_init(ks[2], (cfg.d_model, 2 * G * N), dt),
        "in_proj_dt": dense_init(ks[3], (cfg.d_model, H), dt),
        "conv_x": dense_init(ks[4], (s.d_conv, d_inner), dt, fan_in=s.d_conv),
        "conv_bc": dense_init(ks[5], (s.d_conv, 2 * G * N), dt,
                              fan_in=s.d_conv),
        "A_log": jnp.zeros((H,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((d_inner,), dt),
        "out_proj": dense_init(ks[6], (d_inner, cfg.d_model), dt),
    }


def _project(p, x, cfg):
    """x: [B,S,D] -> (z, xs, BC, dt) via the per-stream projections."""
    z = jnp.einsum("bsd,de->bse", x, p["in_proj_z"])
    xs = jnp.einsum("bsd,de->bse", x, p["in_proj_x"])
    bc = jnp.einsum("bsd,de->bse", x, p["in_proj_bc"])
    dtp = jnp.einsum("bsd,de->bse", x, p["in_proj_dt"])
    return z, xs, bc, dtp


def _conv(xBC, w, state=None):
    """Causal depthwise conv over seq. xBC: [B, S, Cd], w: [K, Cd].

    ``state``: optional [B, K-1, Cd] of previous inputs (prefill=zeros).
    Returns (y [B, S, Cd], new_state [B, K-1, Cd])."""
    K = w.shape[0]
    xpad = jnp.concatenate(
        [jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
         if state is None else state.astype(xBC.dtype), xBC], axis=1)
    y = sum(xpad[:, i : i + xBC.shape[1]] * w[i][None, None] for i in range(K))
    new_state = xpad[:, xBC.shape[1]:]
    return jax.nn.silu(y), new_state


def _segsum(a):
    """a: [..., L] log-decays -> [..., L, L] cumulative sums over (s, t]:
    out[t, s] = sum_{r=s+1..t} a_r for s < t, 0 on diag, -inf above."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # [t, s] = cs_t - cs_s
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, h0=None):
    """Chunked SSD scan.

    x: [b, S, H, P]; dt: [b, S, H] (>=0); A: [H] (<0);
    B, C: [b, S, G, N] (G divides H). Returns (y [b,S,H,P], h_T [b,H,P,N]).
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2)  # [b, S, H, N]
    Ch = jnp.repeat(C, rep, axis=2)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk

    def r(t):  # [b, Sp, ...] -> [nc, b, chunk, ...]
        return jnp.moveaxis(t.reshape(b, nc, chunk, *t.shape[2:]), 1, 0)

    xc, dtc, Bc, Cc = r(x), r(dt), r(Bh), r(Ch)
    a = (dtc.astype(jnp.float32) * A[None, None, None]).astype(jnp.float32)
    # within-chunk log-decay matrix per head: [nc, b, H, L, L]
    Lmat = jnp.exp(_segsum(jnp.moveaxis(a, -1, -2)))  # a -> [nc,b,H,L]
    # intra-chunk (diagonal block) output:
    # y[t] += sum_s C_t.B_s dt_s decay(t,s) x_s
    CB = jnp.einsum("cbthn,cbshn->cbhts", Cc, Bc)
    W = CB * Lmat * jnp.moveaxis(dtc, -1, -2)[..., None, :]  # [nc,b,h,t,s]
    y_diag = jnp.einsum("cbhts,cbshp->cbthp", W.astype(x.dtype), xc)
    # chunk states: states_c = sum_s decay(end, s) dt_s B_s (x) x_s
    a_h = jnp.moveaxis(a, -1, -2)  # [nc, b, H, L]
    cum = jnp.cumsum(a_h, axis=-1)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)  # [nc,b,H,L]
    sw = (decay_to_end * jnp.moveaxis(dtc, -1, -2)).astype(x.dtype)
    states = jnp.einsum("cbhs,cbshn,cbshp->cbhpn", sw, Bc, xc)
    chunk_decay = jnp.exp(cum[..., -1])  # [nc, b, H]

    if h0 is None:
        h0 = jnp.zeros((b, H, P, N), jnp.float32)

    def scan_body(h, inp):
        st, cd = inp  # [b,H,P,N], [b,H]
        h_prev = h
        h = h * cd[..., None, None] + st.astype(jnp.float32)
        return h, h_prev

    hT, h_prevs = jax.lax.scan(scan_body, h0.astype(jnp.float32),
                               (states, chunk_decay))
    # inter-chunk output: y[t] += C_t . (decay(t, start) h_prev)
    decay_from_start = jnp.exp(cum).astype(x.dtype)  # [nc,b,H,L]
    y_off = jnp.einsum("cbthn,cbhpn,cbht->cbthp", Cc,
                       h_prevs.astype(x.dtype), decay_from_start)
    y = y_diag + y_off
    y = jnp.moveaxis(y, 0, 1).reshape(b, Sp, H, P)[:, :S]
    return y, hT


def ssd_decode_step(x1, dt1, A, B1, C1, h):
    """One-step recurrence. x1: [b,H,P], dt1: [b,H], B1/C1: [b,G,N],
    h: [b,H,P,N] (f32). Returns (y [b,H,P], h_new)."""
    H = x1.shape[1]
    G = B1.shape[1]
    rep = H // G
    Bh = jnp.repeat(B1, rep, axis=1)  # [b,H,N]
    Ch = jnp.repeat(C1, rep, axis=1)
    decay = jnp.exp(dt1.astype(jnp.float32) * A[None])  # [b,H]
    upd = (dt1[..., None, None].astype(jnp.float32)
           * Bh[:, :, None, :].astype(jnp.float32)
           * x1[..., None].astype(jnp.float32))
    h_new = h * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", h_new.astype(x1.dtype), Ch)
    return y, h_new


def mamba2_forward(p, x, cfg, cache: SSMCache | None = None,
                   return_cache: bool = False):
    """Full-sequence mamba2 block. x: [B, S, D] -> [B, S, D]."""
    s = cfg.ssm
    d_inner, H, P, N, G = _dims(cfg)
    z, xs, bc, dtp = _project(p, x, cfg)
    conv_state = cache.conv if cache is not None else None
    conv_bc_state = cache.conv_bc if cache is not None else None
    xs, conv_state = _conv(xs, p["conv_x"], conv_state)
    bc, conv_bc_state = _conv(bc, p["conv_bc"], conv_bc_state)
    B_, C_ = jnp.split(bc, [G * N], axis=-1)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    b, S = x.shape[0], x.shape[1]
    xh = xs.reshape(b, S, H, P)
    Bm = B_.reshape(b, S, G, N)
    Cm = C_.reshape(b, S, G, N)
    h0 = cache.h if cache is not None else None
    y, hT = ssd_chunked(xh, dt, A, Bm, Cm, chunk=s.chunk, h0=h0)
    y = y + p["D"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(b, S, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    new_cache = None
    if return_cache:
        pos = (cache.pos if cache is not None else 0) + S
        new_cache = SSMCache(h=hT, conv=conv_state, conv_bc=conv_bc_state,
                             pos=jnp.asarray(pos, jnp.int32))
    return out, new_cache


def mamba2_init_cache(cfg, batch: int):
    s = cfg.ssm
    d_inner, H, P, N, G = _dims(cfg)
    dt = jnp.dtype(cfg.compute_dtype)
    return SSMCache(
        h=jnp.zeros((batch, H, P, N), jnp.float32),
        conv=jnp.zeros((batch, s.d_conv - 1, d_inner), dt),
        conv_bc=jnp.zeros((batch, s.d_conv - 1, 2 * G * N), dt),
        pos=jnp.asarray(0, jnp.int32),
    )


def _conv_step(state, x1, w):
    """One causal depthwise-conv step. state: [B,K-1,C], x1: [B,1,C]."""
    conv_in = jnp.concatenate([state.astype(x1.dtype), x1], axis=1)
    y = sum(conv_in[:, i : i + 1] * w[i][None, None]
            for i in range(w.shape[0]))
    return jax.nn.silu(y)[:, 0], conv_in[:, 1:]


def mamba2_decode(p, x1, cfg, cache: SSMCache):
    """One-token decode. x1: [B, 1, D]. Returns (out [B,1,D], cache)."""
    s = cfg.ssm
    d_inner, H, P, N, G = _dims(cfg)
    z, xs, bc, dtp = _project(p, x1, cfg)
    xs1, new_conv = _conv_step(cache.conv, xs, p["conv_x"])
    bc1, new_conv_bc = _conv_step(cache.conv_bc, bc, p["conv_bc"])
    B1, C1 = jnp.split(bc1, [G * N], axis=-1)
    dt1 = jax.nn.softplus(dtp[:, 0].astype(jnp.float32) + p["dt_bias"][None])
    A = -jnp.exp(p["A_log"])
    b = x1.shape[0]
    y, h_new = ssd_decode_step(
        xs1.reshape(b, H, P), dt1, A, B1.reshape(b, G, N), C1.reshape(b, G, N),
        cache.h,
    )
    y = y + p["D"][None, :, None].astype(y.dtype) * xs1.reshape(b, H, P)
    y = y.reshape(b, 1, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, SSMCache(h=h_new, conv=new_conv, conv_bc=new_conv_bc,
                         pos=cache.pos + 1)
