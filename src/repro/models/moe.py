"""Top-k capacity-routed Mixture-of-Experts (GShard-style, scatter form).

TPU adaptation: routing is *grouped* — tokens are routed within their
batch row, so capacity bookkeeping stays local to the data shard and no
global cumsum crosses the data axis (DESIGN.md §4). Dispatch/combine use
scatter/gather instead of the [T, E, C] one-hot einsum, which would be
~10^10 elements at train_4k scale.

Expert FFNs are SwiGLU with weights stacked [E, ...]; the hidden dim is
the TP-sharded axis (experts stay resident — "tensor-parallel experts" —
because 40 and 8 experts don't divide the 16-way model axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init


def moe_init(key, cfg):
    m = cfg.moe
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    E, D, F = m.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": dense_init(ks[0], (D, E), dt),
        "w_gate": dense_init(ks[1], (E, D, F), dt, fan_in=D),
        "w_up": dense_init(ks[2], (E, D, F), dt, fan_in=D),
        "w_down": dense_init(ks[3], (E, F, D), dt, fan_in=F),
    }


def _route_group(x, p, cfg):
    """x: [T, D] one group. Returns (y [T, D], aux_loss scalar)."""
    m = cfg.moe
    T, D = x.shape
    E, k = m.n_experts, m.top_k
    C = max(int(m.capacity_factor * k * T / E), 1)

    logits = jnp.einsum("td,de->te", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # [T, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Position-in-expert per (token, slot), sequential over slots;
    # dispatch/combine as GShard one-hot einsums (scatter/gather defeats
    # GSPMD sharding propagation — it replicated the group dim and
    # partial-summed the FSDP dim; see EXPERIMENTS.md §Perf).
    counts = jnp.zeros((E,), jnp.int32)
    dispatch = jnp.zeros((T, E, C), x.dtype)
    combine = jnp.zeros((T, E, C), x.dtype)
    for slot in range(k):
        e = expert_idx[:, slot]  # [T]
        onehot = jax.nn.one_hot(e, E, dtype=jnp.int32)  # [T, E]
        pos = jnp.cumsum(onehot, axis=0) - 1
        pos = jnp.take_along_axis(pos, e[:, None], axis=1)[:, 0] + counts[e]
        keep = pos < C
        pos_c = jnp.where(keep, pos, C - 1)
        oh_pos = (jax.nn.one_hot(pos_c, C, dtype=x.dtype)
                  * keep[:, None].astype(x.dtype))  # [T, C]
        slot_disp = onehot.astype(x.dtype)[:, :, None] * oh_pos[:, None, :]
        dispatch = dispatch + slot_disp
        combine = combine + slot_disp * gate_vals[:, slot, None, None
                                                  ].astype(x.dtype)
        counts = counts + jnp.sum(onehot, axis=0)

    from ..dist import ctx as CTX

    xin = jnp.einsum("tec,td->ecd", dispatch, x)
    # Expert FFNs: [E, C, D] -> [E, C, D]. Constrain the hidden dim to
    # 'model' (Megatron column-parallel): without it GSPMD contracts the
    # FSDP-sharded D and all-reduces a FULL-d_ff f32 partial instead
    # (9.4 GB/buffer on mixtral prefill — EXPERIMENTS.md §Perf).
    g = CTX.constrain(jnp.einsum("ecd,edf->ecf", xin, p["w_gate"]),
                      None, None, "model")
    u = CTX.constrain(jnp.einsum("ecd,edf->ecf", xin, p["w_up"]),
                      None, None, "model")
    xout = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"])
    y = jnp.einsum("tec,ecd->td", combine, xout)

    # Load-balance auxiliary loss (Switch-style): E * sum_e f_e * P_e.
    frac = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), 0)
    imp = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * imp)
    return y, aux


MOE_SEQ_CHUNK = 2048


def moe_ffn(p, x, cfg):
    """x: [B, S, D] -> (y [B, S, D], aux scalar).

    Groups = batch rows, additionally chunked along seq at 4096 tokens:
    capacity bookkeeping stays per-chunk, which bounds the [E, C, D]
    dispatch buffers for 32k+ prefill (mixtral prefill_32k would
    otherwise build 671 MB/expert-group buffers) and improves balance.
    """
    B, S, D = x.shape
    c = min(MOE_SEQ_CHUNK, S)
    if S % c:
        c = S  # fall back to one group per row for odd smoke shapes
    # Keep (batch, chunk) as TWO vmapped dims: batch may be data-sharded
    # and the chunk dim model-sharded (Megatron-SP seq sharding);
    # collapsing them into one group dim forces GSPMD to replicate.
    xg = x.reshape(B, S // c, c, D)
    y, aux = jax.vmap(jax.vmap(lambda xb: _route_group(xb, p, cfg)))(xg)
    return y.reshape(B, S, D), jnp.mean(aux)
