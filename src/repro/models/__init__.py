"""Model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM backbones."""
from . import attention, hybrid, layers, mamba2, model, moe, transformer, whisper
from .model import decode_step, init, init_cache, loss, prefill
