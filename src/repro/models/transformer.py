"""Decoder-only stack covering the dense / moe / ssm / vlm families.

Layers are homogeneous and scanned (stacked params [L, ...]) so the HLO
stays one-layer-sized; ``cfg.remat`` wraps the scan body in
jax.checkpoint. The hybrid (zamba2) and enc-dec (whisper) families build
on these pieces in hybrid.py / whisper.py.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import attention as A
from . import mamba2 as M
from . import moe as X
from .layers import embed_init, mlp_init, rmsnorm, swiglu


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------

def layer_init(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    p = {}
    if cfg.family == "ssm":
        p["norm_ssm"] = jnp.ones((cfg.d_model,), dt)
        p["ssm"] = M.mamba2_init(key, cfg)
        return p
    k1, k2 = jax.random.split(key)
    p["norm_attn"] = jnp.ones((cfg.d_model,), dt)
    p["attn"] = A.attn_init(k1, cfg)
    p["norm_ffn"] = jnp.ones((cfg.d_model,), dt)
    if cfg.family == "moe":
        p["moe"] = X.moe_init(k2, cfg)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dt)
    return p


def layer_forward(p, h, cfg, *, positions, window="cfg", make_cache=False,
                  cache_len=None):
    """Full-seq layer. Returns (h, cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        out, cache = M.mamba2_forward(
            p["ssm"], rmsnorm(h, p["norm_ssm"], cfg.norm_eps), cfg,
            return_cache=make_cache)
        return h + out, cache, aux
    attn_out, cache = A.attn_forward(
        p["attn"], rmsnorm(h, p["norm_attn"], cfg.norm_eps), cfg,
        positions=positions, window=window, make_cache=make_cache,
        cache_len=cache_len)
    h = h + attn_out
    hn = rmsnorm(h, p["norm_ffn"], cfg.norm_eps)
    if cfg.family == "moe":
        ffn_out, aux = X.moe_ffn(p["moe"], hn, cfg)
    else:
        ffn_out = swiglu(hn, **p["mlp"])
    return h + ffn_out, cache, aux


def layer_decode(p, h, cfg, cache, *, window="cfg"):
    """Single-token layer. Returns (h, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        out, cache = M.mamba2_decode(
            p["ssm"], rmsnorm(h, p["norm_ssm"], cfg.norm_eps), cfg, cache)
        return h + out, cache, aux
    attn_out, cache = A.attn_decode(
        p["attn"], rmsnorm(h, p["norm_attn"], cfg.norm_eps), cfg, cache,
        window=window)
    h = h + attn_out
    hn = rmsnorm(h, p["norm_ffn"], cfg.norm_eps)
    if cfg.family == "moe":
        ffn_out, aux = X.moe_ffn(p["moe"], hn, cfg)
    else:
        ffn_out = swiglu(hn, **p["mlp"])
    return h + ffn_out, cache, aux


# ---------------------------------------------------------------------------
# Stack
# ---------------------------------------------------------------------------

def init(key, cfg):
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    layers = jax.vmap(lambda k: layer_init(k, cfg))(layer_keys)
    p = {
        "embed": embed_init(ks[1], (cfg.vocab, cfg.d_model), dt),
        "layers": layers,
        "norm_f": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(ks[2], (cfg.d_model, cfg.vocab), dt)
    return p


def _embed_tokens(p, cfg, tokens):
    h = jnp.take(p["embed"], tokens, axis=0)
    return h.astype(jnp.dtype(cfg.compute_dtype))


def embed_inputs(p, cfg, batch):
    """tokens (+ stubbed modality embeddings) -> (h [B,S,D], n_prefix)."""
    h = _embed_tokens(p, cfg, batch["tokens"])
    n_prefix = 0
    if cfg.family == "vlm" and "patches" in batch:
        patches = batch["patches"].astype(h.dtype)
        h = jnp.concatenate([patches, h], axis=1)
        n_prefix = patches.shape[1]
    return h, n_prefix


def unembed(p, cfg, h):
    from .layers import _dot
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    if h.ndim == 3:
        return _dot(h, w)
    return jnp.einsum("...d,dv->...v", h, w)


def forward(p, cfg, batch, *, window="cfg", make_cache=False,
            cache_len=None, return_hidden=False):
    """Train / prefill forward. Returns (logits or hidden, caches)."""
    h, _ = embed_inputs(p, cfg, batch)
    B, S = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    from ..dist import ctx as CTX

    def body(carry, lp):
        h, aux = carry
        h, cache, a = layer_forward(
            lp, h, cfg, positions=positions, window=window,
            make_cache=make_cache, cache_len=cache_len)
        if h.shape[1] >= 8192:
            # Megatron-SP: sequence-shard the residual stream between
            # layers for long sequences (prefill_32k/long_500k) — keeps
            # the scan carry + remat buffers at S/tp per chip. Batch is
            # pinned to the data axes (only the serve path reaches seq
            # >= 8192; train microbatches are shorter).
            h = CTX.constrain(h, ("pod", "data"), "model", None)
        return (h, aux + a), cache

    body_fn = jax.checkpoint(body) if cfg.remat else body
    nb = cfg.remat_block
    if cfg.remat and nb > 1 and cfg.n_layers % nb == 0 and not make_cache:
        # Two-level remat: store only every nb-th layer boundary; the
        # backward recomputes a block then remats per layer within it.
        blocked = jax.tree.map(
            lambda x: x.reshape((cfg.n_layers // nb, nb) + x.shape[1:]),
            p["layers"])

        def block_body(carry, bp):
            out, _ = jax.lax.scan(body_fn, carry, bp)
            return out, None

        (h, aux), _ = jax.lax.scan(jax.checkpoint(block_body),
                                   (h, jnp.zeros((), jnp.float32)), blocked)
        caches = None
    else:
        (h, aux), caches = jax.lax.scan(
            body_fn, (h, jnp.zeros((), jnp.float32)), p["layers"])
    h = rmsnorm(h, p["norm_f"], cfg.norm_eps)
    if return_hidden:
        return h, caches, aux
    return unembed(p, cfg, h), caches, aux


def init_cache(cfg, batch_size: int, max_len: int, window="cfg"):
    window = cfg.sliding_window if window == "cfg" else window
    if cfg.family == "ssm":
        one = M.mamba2_init_cache(cfg, batch_size)
    else:
        one = A.init_cache(cfg, batch_size, max_len, window=window)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), one)


def decode_step(p, cfg, caches, token, *, window="cfg"):
    """One decode step. token: [B] int32. Returns (logits [B,V], caches)."""
    h = _embed_tokens(p, cfg, token[:, None])

    def body(carry, lp_cache):
        h, aux = carry
        lp, cache = lp_cache
        h, new_cache, a = layer_decode(lp, h, cfg, cache, window=window)
        return (h, aux + a), new_cache

    (h, _), new_caches = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), (p["layers"], caches))
    h = rmsnorm(h, p["norm_f"], cfg.norm_eps)
    return unembed(p, cfg, h)[:, 0], new_caches


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def chunked_ce(p, cfg, hidden, labels, mask=None):
    """Sequence-chunked cross-entropy: never materializes [B, S, V].

    hidden: [B, S, D]; labels: [B, S] int32; mask: [B, S] float weights.
    """
    B, S, D = hidden.shape
    chunk = min(cfg.loss_chunk, S)
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else \
            jnp.pad(jnp.ones((B, S), jnp.float32), ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    hc = jnp.moveaxis(hidden.reshape(B, n, chunk, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0)

    def body(acc, inp):
        h, l, m = inp
        logits = unembed(p, cfg, h).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        loss = jnp.sum((lse - gold) * m)
        return (acc[0] + loss, acc[1] + jnp.sum(m)), None

    # Remat: recompute each chunk's logits in the backward instead of
    # keeping [n_chunks, B, chunk, V] f32 residuals alive.
    body_fn = jax.checkpoint(body) if n > 1 else body
    (tot, cnt), _ = jax.lax.scan(body_fn, (jnp.zeros(()), jnp.zeros(())),
                                 (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(p, cfg, batch, *, window="cfg"):
    """Next-token LM loss (+ MoE aux) for one batch of tokens."""
    h, caches, aux = forward(p, cfg, batch, window=window, return_hidden=True)
    tokens = batch["tokens"]
    n_prefix = h.shape[1] - tokens.shape[1]
    h_txt = h[:, n_prefix:] if n_prefix else h
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
    loss = chunked_ce(p, cfg, h_txt, labels, mask)
    return loss + 0.01 * aux
