"""Unified model API dispatching on cfg.family.

    init(key, cfg)                      -> params
    loss(params, cfg, batch)            -> scalar LM loss
    prefill(params, cfg, batch)         -> (logits [B,S,V], caches)
    init_cache(cfg, batch, max_len)     -> caches (for decode-only entry)
    decode_step(params, cfg, caches, token) -> (logits [B,V], caches)

``window`` semantics: "cfg" uses cfg.sliding_window; an int overrides it
(the long_500k SWA variant for dense archs — DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import hybrid, transformer, whisper

_TRANSFORMER_FAMILIES = ("dense", "moe", "ssm", "vlm")


def init(key, cfg: ArchConfig):
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.init(key, cfg)
    if cfg.family == "hybrid":
        return hybrid.init(key, cfg)
    if cfg.family == "encdec":
        return whisper.init(key, cfg)
    raise ValueError(cfg.family)


def abstract_init(cfg: ArchConfig, seed: int = 0):
    """Shape-only params (no allocation) for the dry-run."""
    return jax.eval_shape(lambda k: init(k, cfg), jax.random.PRNGKey(seed))


def loss(params, cfg: ArchConfig, batch, window="cfg"):
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.lm_loss(params, cfg, batch, window=window)
    if cfg.family == "hybrid":
        h, _, aux = hybrid.forward(params, cfg, batch, return_hidden=True)
    elif cfg.family == "encdec":
        h, _, aux = whisper.forward(params, cfg, batch, return_hidden=True)
    else:
        raise ValueError(cfg.family)
    tokens = batch["tokens"]
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
    p_like = {"embed": params["embed"]}
    ce = transformer.chunked_ce(p_like, cfg, h, labels, mask)
    return ce + 0.01 * aux


def prefill(params, cfg: ArchConfig, batch, window="cfg", cache_len=None,
            last_only: bool = False):
    """``last_only``: return logits for the final position only [B, 1, V]
    (the serving path — avoids materializing [B, S, V])."""
    kw = dict(make_cache=True, cache_len=cache_len, return_hidden=True)
    if cfg.family in _TRANSFORMER_FAMILIES:
        h, caches, _ = transformer.forward(params, cfg, batch,
                                           window=window, **kw)
    elif cfg.family == "hybrid":
        h, caches, _ = hybrid.forward(params, cfg, batch, **kw)
    elif cfg.family == "encdec":
        h, caches, _ = whisper.forward(params, cfg, batch, **kw)
    else:
        raise ValueError(cfg.family)
    if last_only:
        h = h[:, -1:]
    if cfg.family in _TRANSFORMER_FAMILIES:
        logits = transformer.unembed(params, cfg, h)
    else:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    return logits, caches


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int, window="cfg"):
    window = cfg.sliding_window if window == "cfg" else window
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.init_cache(cfg, batch_size, max_len, window=window)
    if cfg.family == "hybrid":
        return hybrid.init_cache(cfg, batch_size, max_len, window=window)
    if cfg.family == "encdec":
        return whisper.init_cache(cfg, batch_size, max_len, window=window)
    raise ValueError(cfg.family)


def decode_step(params, cfg: ArchConfig, caches, token, window="cfg"):
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.decode_step(params, cfg, caches, token,
                                       window=window)
    if cfg.family == "hybrid":
        return hybrid.decode_step(params, cfg, caches, token)
    if cfg.family == "encdec":
        return whisper.decode_step(params, cfg, caches, token)
    raise ValueError(cfg.family)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def active_param_count(params, cfg: ArchConfig) -> int:
    """MoE-aware active parameter count (for MODEL_FLOPS = 6*N_active*D)."""
    total = param_count(params)
    if cfg.moe is None:
        return total
    m = cfg.moe
    expert_leaves = 0
    layers = params.get("layers", {})
    moe_p = layers.get("moe", None) if isinstance(layers, dict) else None
    if moe_p is not None:
        for name in ("w_gate", "w_up", "w_down"):
            expert_leaves += moe_p[name].size
    inactive = expert_leaves * (1 - m.top_k / m.n_experts)
    return int(total - inactive)
