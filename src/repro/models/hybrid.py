"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block
applied every ``cfg.hybrid_attn_every`` layers (arXiv:2411.15242).

Faithful-to-structure simplifications (DESIGN.md §4): the shared block's
input is concat(hidden, initial_embedding) -> down-projection -> attn +
MLP (Zamba's concatenated-residual trick); per-application LoRA deltas
are omitted. The shared block's KV cache is distinct per application.

Layout: G = n_layers // every groups of ``every`` mamba layers, each
followed by the shared block; ``tail`` remaining mamba layers at the end.
Both levels are scanned.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import attention as A
from . import mamba2 as M
from .layers import dense_init, embed_init, mlp_init, rmsnorm, swiglu


class HybridCache(NamedTuple):
    mamba_g: any   # grouped mamba caches, leaves [G, every, ...]
    attn_g: any    # shared-block KV caches, leaves [G, ...]
    mamba_t: any   # tail mamba caches, leaves [tail, ...]


def _split(cfg):
    every = cfg.hybrid_attn_every
    G = cfg.n_layers // every
    tail = cfg.n_layers - G * every
    return every, G, tail


def init(key, cfg):
    every, G, tail = _split(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)

    def m_init(k):
        return {"norm": jnp.ones((cfg.d_model,), dt),
                "ssm": M.mamba2_init(k, cfg)}

    mg_keys = jax.random.split(ks[0], G * every).reshape(G, every, 2)
    mamba_g = jax.vmap(jax.vmap(m_init))(mg_keys)
    mamba_t = jax.vmap(m_init)(jax.random.split(ks[1], max(tail, 1)))
    shared = {
        "in_proj": dense_init(ks[2], (2 * cfg.d_model, cfg.d_model), dt),
        "norm_attn": jnp.ones((cfg.d_model,), dt),
        "attn": A.attn_init(ks[3], cfg),
        "norm_ffn": jnp.ones((cfg.d_model,), dt),
        "mlp": mlp_init(ks[4], cfg.d_model, cfg.d_ff, dt),
    }
    return {
        "embed": embed_init(ks[5], (cfg.vocab, cfg.d_model), dt),
        "mamba_g": mamba_g,
        "mamba_t": mamba_t,
        "shared": shared,
        "norm_f": jnp.ones((cfg.d_model,), dt),
    }


def _mamba_layer(lp, h, cfg, cache=None, make_cache=False, decode=False):
    hn = rmsnorm(h, lp["norm"], cfg.norm_eps)
    if decode:
        out, c = M.mamba2_decode(lp["ssm"], hn, cfg, cache)
    else:
        out, c = M.mamba2_forward(lp["ssm"], hn, cfg, cache=cache,
                                  return_cache=make_cache)
    return h + out, c


def _shared_block(sp, h, h0, cfg, *, positions=None, cache=None,
                  decode=False, make_cache=False, cache_len=None):
    x = jnp.concatenate([h, h0], axis=-1)
    x = jnp.einsum("bse,ed->bsd", x, sp["in_proj"])
    xn = rmsnorm(x, sp["norm_attn"], cfg.norm_eps)
    if decode:
        attn_out, c = A.attn_decode(sp["attn"], xn, cfg, cache)
    else:
        attn_out, c = A.attn_forward(sp["attn"], xn, cfg, positions=positions,
                                     make_cache=make_cache,
                                     cache_len=cache_len)
    x = x + attn_out
    x = x + swiglu(rmsnorm(x, sp["norm_ffn"], cfg.norm_eps), **sp["mlp"])
    return h + x, c


def forward(p, cfg, batch, *, make_cache=False, cache_len=None,
            return_hidden=False):
    tokens = batch["tokens"]
    h = jnp.take(p["embed"], tokens, axis=0).astype(jnp.dtype(cfg.compute_dtype))
    h0 = h
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    every, G, tail = _split(cfg)

    def group_body(carry, gp):
        h = carry

        def inner(h, lp):
            h, c = _mamba_layer(lp, h, cfg, make_cache=make_cache)
            return h, c

        inner_fn = jax.checkpoint(inner) if cfg.remat else inner
        h, m_caches = jax.lax.scan(inner_fn, h, gp)
        h, a_cache = _shared_block(p["shared"], h, h0, cfg,
                                   positions=positions,
                                   make_cache=make_cache, cache_len=cache_len)
        return h, (m_caches, a_cache)

    h, (mg_caches, ag_caches) = jax.lax.scan(group_body, h, p["mamba_g"])

    def tail_body(h, lp):
        h, c = _mamba_layer(lp, h, cfg, make_cache=make_cache)
        return h, c

    if tail:
        h, mt_caches = jax.lax.scan(tail_body, h, p["mamba_t"])
    else:
        mt_caches = None
    h = rmsnorm(h, p["norm_f"], cfg.norm_eps)
    caches = HybridCache(mg_caches, ag_caches, mt_caches) if make_cache else None
    if return_hidden:
        return h, caches, jnp.zeros((), jnp.float32)
    logits = jnp.einsum("bsd,vd->bsv", h, p["embed"])
    return logits, caches, jnp.zeros((), jnp.float32)


def init_cache(cfg, batch_size: int, max_len: int, window=None):
    every, G, tail = _split(cfg)
    m1 = M.mamba2_init_cache(cfg, batch_size)
    a1 = A.init_cache(cfg, batch_size, max_len, window=window)

    def stack(tree, *dims):
        def f(x):
            for d in reversed(dims):
                x = jnp.broadcast_to(x[None], (d,) + x.shape)
            return x
        return jax.tree.map(f, tree)

    return HybridCache(
        mamba_g=stack(m1, G, every),
        attn_g=stack(a1, G),
        mamba_t=stack(m1, tail) if tail else None,
    )


def decode_step(p, cfg, caches: HybridCache, token):
    h = jnp.take(p["embed"], token[:, None], axis=0).astype(
        jnp.dtype(cfg.compute_dtype))
    h0 = h
    every, G, tail = _split(cfg)

    def group_body(h, xs):
        gp, m_caches, a_cache = xs

        def inner(h, lp_c):
            lp, c = lp_c
            h, c = _mamba_layer(lp, h, cfg, cache=c, decode=True)
            return h, c

        h, m_new = jax.lax.scan(inner, h, (gp, m_caches))
        h, a_new = _shared_block(p["shared"], h, h0, cfg, cache=a_cache,
                                 decode=True)
        return h, (m_new, a_new)

    h, (mg_new, ag_new) = jax.lax.scan(
        group_body, h, (p["mamba_g"], caches.mamba_g, caches.attn_g))

    if tail:
        def tail_body(h, lp_c):
            lp, c = lp_c
            h, c = _mamba_layer(lp, h, cfg, cache=c, decode=True)
            return h, c

        h, mt_new = jax.lax.scan(tail_body, h, (p["mamba_t"], caches.mamba_t))
    else:
        mt_new = None
    h = rmsnorm(h, p["norm_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", h, p["embed"])[:, 0]
    return logits, HybridCache(mg_new, ag_new, mt_new)
