"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The mel-spectrogram + conv feature extractor is a STUB per the brief:
``batch['frames']`` supplies precomputed frame embeddings
[B, n_frames, d_model]. Sinusoidal positions, pre-norm transformer,
no RoPE (cfg.rope=False). Decoder layers: causal self-attn (cached) +
cross-attn over the encoder output (cross K/V precomputed at prefill) +
MLP. Both stacks are scanned.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import attention as A
from .layers import embed_init, mlp_init, rmsnorm, sinusoidal_positions, swiglu


class EncDecCache(NamedTuple):
    self_kv: any   # [L, ...] decoder self-attention caches
    cross_kv: any  # [L, ...] precomputed cross K/V


def _enc_layer_init(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {
        "norm_attn": jnp.ones((cfg.d_model,), dt),
        "attn": A.attn_init(k1, cfg),
        "norm_ffn": jnp.ones((cfg.d_model,), dt),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dt),
    }


def _dec_layer_init(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm_self": jnp.ones((cfg.d_model,), dt),
        "self": A.attn_init(k1, cfg),
        "norm_cross": jnp.ones((cfg.d_model,), dt),
        "cross": A.attn_init(k2, cfg),
        "norm_ffn": jnp.ones((cfg.d_model,), dt),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, dt),
    }


def init(key, cfg):
    ks = jax.random.split(key, 3)
    enc_keys = jax.random.split(ks[0], cfg.encoder.n_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "embed": embed_init(ks[2], (cfg.vocab, cfg.d_model), dt),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
        "norm_enc": jnp.ones((cfg.d_model,), dt),
        "norm_f": jnp.ones((cfg.d_model,), dt),
    }


def encode(p, cfg, frames):
    """frames: [B, F, D] stubbed frontend embeddings -> [B, F, D]."""
    dt = jnp.dtype(cfg.compute_dtype)
    h = frames.astype(dt) + sinusoidal_positions(
        frames.shape[1], cfg.d_model, dt)[None]

    def body(h, lp):
        hn = rmsnorm(h, lp["norm_attn"], cfg.norm_eps)
        out, _ = A.attn_forward(lp["attn"], hn, cfg, positions=None,
                                causal=False, window=None)
        h = h + out
        h = h + swiglu(rmsnorm(h, lp["norm_ffn"], cfg.norm_eps), **lp["mlp"])
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, p["enc_layers"])
    return rmsnorm(h, p["norm_enc"], cfg.norm_eps)


def _dec_layer(lp, h, cfg, enc_out, *, make_cache=False, cache_len=None):
    hn = rmsnorm(h, lp["norm_self"], cfg.norm_eps)
    out, self_cache = A.attn_forward(lp["self"], hn, cfg, positions=None,
                                     causal=True, window=None,
                                     make_cache=make_cache,
                                     cache_len=cache_len)
    h = h + out
    hn = rmsnorm(h, lp["norm_cross"], cfg.norm_eps)
    out, _ = A.attn_forward(lp["cross"], hn, cfg, positions=None,
                            causal=False, window=None, kv_x=enc_out)
    h = h + out
    h = h + swiglu(rmsnorm(h, lp["norm_ffn"], cfg.norm_eps), **lp["mlp"])
    cross_cache = A.make_cross_cache(lp["cross"], enc_out, cfg) \
        if make_cache else None
    return h, self_cache, cross_cache


def forward(p, cfg, batch, *, make_cache=False, cache_len=None,
            return_hidden=False):
    enc_out = encode(p, cfg, batch["frames"])
    tokens = batch["tokens"]
    dt = jnp.dtype(cfg.compute_dtype)
    h = jnp.take(p["embed"], tokens, axis=0).astype(dt)
    h = h + sinusoidal_positions(h.shape[1], cfg.d_model, dt)[None]

    def body(h, lp):
        h, sc, cc = _dec_layer(lp, h, cfg, enc_out, make_cache=make_cache,
                               cache_len=cache_len)
        return h, (sc, cc)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, (self_caches, cross_caches) = jax.lax.scan(body_fn, h, p["dec_layers"])
    h = rmsnorm(h, p["norm_f"], cfg.norm_eps)
    caches = EncDecCache(self_caches, cross_caches) if make_cache else None
    aux = jnp.zeros((), jnp.float32)
    if return_hidden:
        return h, caches, aux
    return jnp.einsum("bsd,vd->bsv", h, p["embed"]), caches, aux


def init_cache(cfg, batch_size: int, max_len: int, window=None):
    self1 = A.init_cache(cfg, batch_size, max_len, window=window)
    dtc = jnp.dtype(cfg.compute_dtype)
    F = cfg.encoder.n_frames
    cross1 = A.KVCache(
        k=jnp.zeros((batch_size, F, cfg.n_kv_heads, cfg.head_dim), dtc),
        v=jnp.zeros((batch_size, F, cfg.n_kv_heads, cfg.head_dim), dtc),
        pos=jnp.asarray(F, jnp.int32),
    )
    L = cfg.n_layers
    stack = lambda t: jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (L,) + x.shape), t)
    return EncDecCache(self_kv=stack(self1), cross_kv=stack(cross1))


def decode_step(p, cfg, caches: EncDecCache, token):
    dt = jnp.dtype(cfg.compute_dtype)
    h = jnp.take(p["embed"], token[:, None], axis=0).astype(dt)
    # absolute position = self-cache fill level (same for every layer);
    # scalar in the classic path, per-row [B] under the slot cache.
    pos = caches.self_kv.pos[0]
    half = cfg.d_model // 2
    div = jnp.exp(jnp.arange(half, dtype=jnp.float32)
                  * (-jnp.log(10000.0) / cfg.d_model) * 2.0)
    ang = pos.astype(jnp.float32)[..., None] * div  # [..., half]
    pe = jnp.zeros(ang.shape[:-1] + (cfg.d_model,), jnp.float32)
    pe = pe.at[..., 0::2].set(jnp.sin(ang))
    pe = pe.at[..., 1::2].set(jnp.cos(ang[..., : cfg.d_model - half]))
    pe = pe.astype(dt)
    h = h + (pe[:, None] if pos.ndim else pe[None, None])

    def body(h, xs):
        lp, sc, cc = xs
        hn = rmsnorm(h, lp["norm_self"], cfg.norm_eps)
        out, sc_new = A.attn_decode(lp["self"], hn, cfg, sc, window=None)
        h = h + out
        hn = rmsnorm(h, lp["norm_cross"], cfg.norm_eps)
        h = h + A.cross_attn_decode(lp["cross"], hn, cfg, cc)
        h = h + swiglu(rmsnorm(h, lp["norm_ffn"], cfg.norm_eps), **lp["mlp"])
        return h, sc_new

    h, self_new = jax.lax.scan(body, h, (p["dec_layers"], caches.self_kv,
                                         caches.cross_kv))
    h = rmsnorm(h, p["norm_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", h, p["embed"])[:, 0]
    return logits, EncDecCache(self_new, caches.cross_kv)
