"""Shared building blocks: norms, embeddings, RoPE, init helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(max(fan_in, 1), jnp.float32))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def rmsnorm(x, scale, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def rope(x, positions, theta: float = 10000.0):
    """Rotary embedding. x: [..., S, H, dh]; positions: [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int, dtype=jnp.float32):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div[: (d + 1) // 2]))
    return pe.astype(dtype)


def _dot(x, w):
    """x @ w with optional in-backward robust weight-grad reduce (IB-RRS,
    DESIGN.md §2) when repro.dist.robust_reduce.robust_backward is active."""
    from ..dist import robust_reduce as RR

    if RR.robust_dot_enabled() and x.ndim == 3 and w.ndim == 2:
        return RR.robust_dot(x, w)
    return jnp.einsum("...d,df->...f", x, w)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP: (silu(x@wg) * (x@wu)) @ wd."""
    g = jax.nn.silu(_dot(x, w_gate))
    u = _dot(x, w_up)
    return _dot(g * u, w_down)


def mlp_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }
