"""GQA attention: chunked (flash-style) full-sequence path + cached decode.

Memory design: scores are never materialized at [B, H, S, S]; the query
axis is processed in blocks of ``cfg.attn_chunk`` via lax.scan, keeping
the live buffer at [B, Hkv, Hq/Hkv, blk, T]. GQA is computed grouped
(no repeat of K/V). Sliding-window masking supports Mixtral-style SWA
and the long_500k dense variant; decode uses a ring-buffer cache when a
window is set.

Execution is backend-dispatched (DESIGN.md §8): ``attn_forward`` and
``attn_decode``/``cross_attn_decode`` route through
``models/attn_backend.py``, which sends supported signatures to the
fused Pallas kernels (``kernels/flash_attention`` full-sequence,
``kernels/decode_attention`` single-query grouped-GQA decode) per
``cfg.attn_backend``; the chunked ``mha`` below is the jnp reference
backend and the only implementation of sliding-window masking and the
TP head-padded layout.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm, rope

NEG_INF = -1e30

# KV-cache storage dtypes (cfg.kv_dtype, DESIGN.md §12). Quantization is
# write-side only: Q/K/V are computed in compute_dtype, the cache stores
# the narrow form, and dequantization happens at read time (fused into
# the decode-attention kernel's block loads on the flash backend).
KV_DTYPES = ("float32", "bfloat16", "int8")


class KVCache(NamedTuple):
    k: jnp.ndarray  # [B, T, Hkv, dh] (T = max_len or window size)
    v: jnp.ndarray
    pos: jnp.ndarray  # [] int32 — number of tokens already written
    # int8 KV only: per-(row, position) f32 dequant scales [B, T],
    # carried beside the cache exactly like ``pos`` (None otherwise, so
    # unquantized cache trees keep their pre-§12 structure — None is not
    # a pytree leaf and every structural probe/tree.map skips it).
    k_scale: Optional[jnp.ndarray] = None
    v_scale: Optional[jnp.ndarray] = None


def kv_dtype(cfg):
    """The cache storage dtype: ``cfg.kv_dtype`` or compute_dtype."""
    return jnp.dtype(getattr(cfg, "kv_dtype", None) or cfg.compute_dtype)


def quantize_kv(x, dt):
    """Quantize fresh K/V rows ``[B, S, Hkv, dh]`` for cache storage.

    Returns ``(stored, scale)``: int8 uses a symmetric per-(row,
    position) scale over the [Hkv, dh] tail — each cache position is
    quantized exactly once, at write time, and never requantized — any
    other dtype is a plain cast with ``scale=None``.
    """
    if dt == jnp.int8:
        s = jnp.max(jnp.abs(x), axis=(2, 3)).astype(jnp.float32) / 127.0
        s = jnp.maximum(s, 1e-8)  # all-zero rows (padding) stay zero
        q = jnp.round(x.astype(jnp.float32) / s[:, :, None, None])
        return jnp.clip(q, -127.0, 127.0).astype(jnp.int8), s
    return x.astype(dt), None


def attn_init(key, cfg, d_model=None, cross: bool = False):
    d = d_model or cfg.d_model
    dh = cfg.head_dim
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads, dh), dt, fan_in=d),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads, dh), dt, fan_in=d),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads, dh), dt, fan_in=d),
        "wo": dense_init(ks[3], (cfg.n_heads, dh, d), dt, fan_in=cfg.n_heads * dh),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dt)
        p["k_norm"] = jnp.ones((dh,), dt)
    return p


def _proj(x, w3):
    """[B,S,D] @ [D,H,dh] via the IB-RRS-aware 2-D dot."""
    from .layers import _dot

    D, H, dh = w3.shape
    return _dot(x, w3.reshape(D, H * dh)).reshape(x.shape[:-1] + (H, dh))


def _out_proj(out, wo):
    """[B,S,H,dh] @ [H,dh,D] via the IB-RRS/TP-aware 2-D dot — decode
    shares the sharding/robust-backward contract of ``attn_forward``."""
    from .layers import _dot

    H, dh, D = wo.shape
    return _dot(out.reshape(out.shape[:2] + (H * dh,)), wo.reshape(H * dh, D))


def _qkv(p, x, cfg, positions, kv_x=None):
    kv_x = x if kv_x is None else kv_x
    q = _proj(x, p["wq"])
    k = _proj(kv_x, p["wk"])
    v = _proj(kv_x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def mha(q, k, v, *, causal: bool, window: Optional[int], chunk: int,
        q_offset=0, kv_len: Optional[jnp.ndarray] = None):
    """Chunked multi-head attention, TP-aware.

    q: [B, S, H, dh]; k/v: [B, T, Hkv, dh]. ``q_offset``: absolute
    position of q[0] relative to k[0]. ``kv_len``: optional valid kv
    length (decode with a partially-filled cache) — a scalar, or a
    per-row [B] vector when rows are at different fill levels (the
    slot-cache serving path, DESIGN.md §6). Returns [B, S, H, dh].

    Sharding design (DESIGN.md §5): K/V are repeated to H query heads
    (GQA groups are NOT computed via a reshape of the head axis — a
    reshape of a sharded 16-head axis into [8, 2] forces GSPMD to
    replicate; the repeat keeps a plain head axis that shards cleanly).
    When H doesn't divide the model axis (starcoder2's 36, minitron's
    24), heads are zero-padded up to the next multiple — ~1.3x attention
    flops on those archs, traded for an exact head-sharded layout.
    """
    from ..dist import ctx

    B, S, H, dh = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32)).astype(q.dtype)
    rep = H // Hkv
    if rep > 1:
        # reprolint: disable=RL002 DESIGN §5: TP shards the head axis; a grouped [Hkv, G] reshape of a sharded 16-head axis forces GSPMD replication, so the jnp path repeats pre-shard (flash path stays grouped)
        k = jnp.repeat(k, rep, axis=2)
        # reprolint: disable=RL002 DESIGN §5: same head-sharding constraint as k above
        v = jnp.repeat(v, rep, axis=2)
    tp = ctx.axis_size("model")
    Hp = -(-H // tp) * tp
    if Hp != H:
        padh = ((0, 0), (0, 0), (0, Hp - H), (0, 0))
        q = jnp.pad(q, padh)
        k = jnp.pad(k, padh)
        v = jnp.pad(v, padh)
    if tp > 1:
        q = ctx.constrain(q, ctx.U, ctx.U, "model", None)
        k = ctx.constrain(k, ctx.U, ctx.U, "model", None)
        v = ctx.constrain(v, ctx.U, ctx.U, "model", None)

    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    qp = qp.reshape(B, n_chunks, chunk, Hp, dh)
    kv_pos = jnp.arange(T)

    # Per-row valid-length mask [B, T] (slot cache: rows differ); the
    # scalar case folds into the positional mask below.
    row_valid = None
    if kv_len is not None and getattr(kv_len, "ndim", 0) > 0:
        row_valid = kv_pos[None, :] < kv_len[:, None]

    def body(_, qc_i):
        qc, i = qc_i
        q_pos = q_offset + i * chunk + jnp.arange(chunk)
        s = jnp.einsum("bshd,bthd->bhst", qc * scale, k).astype(jnp.float32)
        mask = jnp.ones((chunk, T), bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        if kv_len is not None and row_valid is None:
            mask &= kv_pos[None, :] < kv_len
        s = jnp.where(mask[None, None], s, NEG_INF)
        if row_valid is not None:
            s = jnp.where(row_valid[:, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return None, jnp.einsum("bhst,bthd->bshd", p, v)

    # Remat the chunk body: without it, the backward of the chunk scan
    # stacks every chunk's f32 scores/probs ([n_chunks, blk, T] live at
    # once); with it, scores are recomputed per chunk in the backward.
    body_fn = jax.checkpoint(body) if n_chunks > 1 else body
    _, out = jax.lax.scan(
        body_fn, None, (jnp.moveaxis(qp, 1, 0), jnp.arange(n_chunks))
    )
    out = jnp.moveaxis(out, 0, 1).reshape(B, n_chunks * chunk, Hp, dh)
    return out[:, :S, :H]


def attn_forward(p, x, cfg, *, positions, causal=True, window="cfg",
                 kv_x=None, make_cache=False, cache_len=None):
    """Full-sequence attention (train / prefill / encoder / cross).

    Returns (out [B,S,D], cache or None). ``window`` overrides
    cfg.sliding_window when given explicitly.
    """
    from . import attn_backend as AB

    window = cfg.sliding_window if window == "cfg" else window
    q, k, v = _qkv(p, x, cfg, positions, kv_x=kv_x)
    out = AB.full_attention(q, k, v, cfg, causal=causal, window=window)
    out = _out_proj(out, p["wo"])
    cache = None
    if make_cache:
        S = k.shape[1]
        if window:
            # Ring cache of exactly `window` slots; position p lives at
            # slot p % window so decode can keep writing in ring order.
            w = window
            if S >= w:
                ck = jnp.roll(k[:, -w:], S % w, axis=1)
                cv = jnp.roll(v[:, -w:], S % w, axis=1)
            else:
                padw = ((0, 0), (0, w - S), (0, 0), (0, 0))
                ck, cv = jnp.pad(k, padw), jnp.pad(v, padw)
        else:
            T = cache_len or S
            if T == S:
                ck, cv = k, v
            elif T > S:
                padw = ((0, 0), (0, T - S), (0, 0), (0, 0))
                ck, cv = jnp.pad(k, padw), jnp.pad(v, padw)
            else:
                ck, cv = k[:, :T], v[:, :T]
        dt = kv_dtype(cfg)
        ck, ks = quantize_kv(ck, dt)
        cv, vs = quantize_kv(cv, dt)
        cache = KVCache(k=ck, v=cv, pos=jnp.asarray(S, jnp.int32),
                        k_scale=ks, v_scale=vs)
    return out, cache


def init_cache(cfg, batch: int, max_len: int, window: Optional[int] = None,
               d_model=None):
    """Empty KV cache. With a window, the cache is a ring of that size."""
    T = min(window, max_len) if window else max_len
    dt = kv_dtype(cfg)
    shape = (batch, T, cfg.n_kv_heads, cfg.head_dim)
    ks = vs = None
    if dt == jnp.int8:
        ks = jnp.zeros((batch, T), jnp.float32)
        vs = jnp.zeros((batch, T), jnp.float32)
    return KVCache(
        k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt),
        pos=jnp.asarray(0, jnp.int32), k_scale=ks, v_scale=vs,
    )


def attn_decode(p, x1, cfg, cache: KVCache, *, window="cfg"):
    """Single-token decode. x1: [B, 1, D]. Returns (out [B,1,D], cache).

    ``cache.pos`` may be a scalar (all rows at the same fill level — the
    classic batched path) or a per-row [B] vector (slot-cache serving,
    DESIGN.md §6): each row then writes its K/V at its own position and
    masks to its own valid length.
    """
    window = cfg.sliding_window if window == "cfg" else window
    pos = cache.pos
    per_row = getattr(pos, "ndim", 0) > 0
    if per_row:
        positions = pos[:, None]
    else:
        positions = pos[None, None] * jnp.ones((x1.shape[0], 1), jnp.int32)
    q, k, v = _qkv(p, x1, cfg, positions)
    T = cache.k.shape[1]
    slot = jnp.mod(pos, T) if window else jnp.minimum(pos, T - 1)
    # quantize the fresh K/V row once, at write time (no-op cast when the
    # cache dtype matches compute_dtype)
    k, ks1 = quantize_kv(k, cache.k.dtype)
    v, vs1 = quantize_kv(v, cache.v.dtype)
    kscale, vscale = cache.k_scale, cache.v_scale
    if per_row:
        upd = jax.vmap(
            lambda c, u, s: jax.lax.dynamic_update_slice(c, u, (s, 0, 0)))
        ck = upd(cache.k, k, slot)
        cv = upd(cache.v, v, slot)
        if ks1 is not None:
            upd1 = jax.vmap(
                lambda c, u, s: jax.lax.dynamic_update_slice(c, u, (s,)))
            kscale = upd1(kscale, ks1, slot)
            vscale = upd1(vscale, vs1, slot)
    else:
        ck = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))
        if ks1 is not None:
            kscale = jax.lax.dynamic_update_slice(kscale, ks1, (0, slot))
            vscale = jax.lax.dynamic_update_slice(vscale, vs1, (0, slot))
    # Ring buffer (window set): all T slots valid once pos >= T; slot
    # positions don't matter for masking beyond validity (window == ring
    # size). Linear cache: the first pos+1 slots are valid.
    kv_len = jnp.minimum(pos + 1, T) if window else pos + 1
    from . import attn_backend as AB

    out = AB.decode_attention(q, ck, cv, cfg, kv_len=kv_len,
                              k_scale=kscale, v_scale=vscale)
    out = _out_proj(out, p["wo"])
    return out, KVCache(k=ck, v=cv, pos=pos + 1,
                        k_scale=kscale, v_scale=vscale)


def cross_attn_decode(p, x1, cfg, cross_kv: KVCache):
    """Decode-time cross attention over a fixed encoder cache."""
    from . import attn_backend as AB

    q = _proj(x1, p["wq"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
    out = AB.decode_attention(q, cross_kv.k, cross_kv.v, cfg)
    return _out_proj(out, p["wo"])


def make_cross_cache(p, enc_out, cfg):
    """Precompute K/V over encoder output for decode-time cross attention."""
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"])
    if cfg.qk_norm:
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return KVCache(k=k, v=v, pos=jnp.asarray(enc_out.shape[1], jnp.int32))
