"""Minimal pytree checkpointing: one .npz payload + a JSON manifest.

``save(path, tree)`` writes every leaf of an arbitrary pytree (params,
optimizer state, scheduler counters) into ``arrays.npz`` in
tree-flatten order plus a ``tree.json`` manifest recording the treedef
string and original dtypes; ``restore(path, like)`` loads them back
into the *structure and shardings* of a template tree — leaves are
``device_put`` onto ``like``'s shardings, so a checkpoint written from
one mesh layout restores onto another without a resharding pass.

bf16 has no npz representation, so bf16 leaves are stored as raw
``uint16`` bit patterns and re-viewed on restore — a bit-exact
round-trip (``tests/test_checkpoint.py``). Restore trusts the
template's treedef rather than re-parsing the manifest; the manifest
exists for tooling and forward-compat checks.

Arrays are gathered to host (fine at the scales we train on CPU; on a
real pod this would be an async per-shard writer — a known scale-out
item, not yet needed by any benchmark).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

_META = "tree.json"
_DATA = "arrays.npz"


def _paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save(path: str, tree) -> None:
    os.makedirs(path, exist_ok=True)
    flat, treedef = _paths(tree)
    arrays, dtypes = {}, []
    for i, x in enumerate(flat):
        a = np.asarray(jax.device_get(x))
        dtypes.append(str(a.dtype))
        if a.dtype == jnp.bfloat16:  # npz has no bf16: store raw bits
            a = a.view(np.uint16)
        arrays[f"a{i}"] = a
    np.savez(os.path.join(path, _DATA), **arrays)
    meta = {"treedef": str(treedef), "n": len(flat), "dtypes": dtypes}
    with open(os.path.join(path, _META), "w") as f:
        json.dump(meta, f)


def restore(path: str, like):
    """Restore into the structure (and shardings) of ``like``."""
    flat_like, treedef = _paths(like)
    with np.load(os.path.join(path, _DATA)) as z:
        flat = [z[f"a{i}"] for i in range(len(flat_like))]
    out = []
    for a, l in zip(flat, flat_like):
        if a.dtype == np.uint16 and jnp.dtype(l.dtype) == jnp.bfloat16:
            a = a.view(jnp.bfloat16)
        x = jnp.asarray(a, dtype=l.dtype)
        if hasattr(l, "sharding") and l.sharding is not None:
            x = jax.device_put(x, l.sharding)
        out.append(x)
    return jax.tree.unflatten(treedef, out)
