"""Synthetic data pipelines for training and the statistical experiments.

Two data families share this module:

* **LM token streams** (``lm_batch`` / ``lm_stream``): deterministic,
  counter-indexed batches of a noisy integer AR process — structured
  enough that next-token loss is learnable, cheap enough for CI. Batches
  are derived host-side from ``(seed, step)`` alone, so a run restored
  from a checkpointed step (``repro.checkpoint``) resumes on exactly the
  data it would have seen. Family-specific extras ride the same dict:
  ``frames`` for encoder-decoder (whisper) configs, ``patches`` for VLM
  configs (which also shorten ``tokens`` to fit the patch prefix).
* **GLM simulation data** for the paper's Section 4 experiments:
  ``Shards`` / ``make_shards`` / ``paper_theta_star`` are re-exported
  from :mod:`repro.core.rcsl` so statistical scripts can import all of
  their data handling from one place.

``shard_batch`` places a host batch onto the mesh with the batch dim
sharded over the batch axes from ``repro.dist.sharding.batch_axes_for``
(DESIGN.md §3 worker-axis conventions).
"""
from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from ..core.rcsl import Shards, make_shards, paper_theta_star  # noqa: F401


def lm_batch(cfg, step: int, batch: int, seq: int, seed: int = 0):
    """Synthetic-but-structured LM batch: a noisy integer AR process, so
    the model has something learnable (next token correlates with prev)."""
    rng = np.random.default_rng(np.uint64(seed * 1_000_003 + step))
    drift = rng.integers(1, 7, size=(batch, 1))
    start = rng.integers(0, cfg.vocab, size=(batch, 1))
    noise = rng.integers(0, 3, size=(batch, seq))
    toks = (start + drift * np.arange(seq)[None, :] + noise) % cfg.vocab
    out = {"tokens": jnp.asarray(toks, jnp.int32)}
    if cfg.family == "encdec":
        f = rng.standard_normal((batch, cfg.encoder.n_frames, cfg.d_model))
        out["frames"] = jnp.asarray(f, jnp.dtype(cfg.compute_dtype))
    elif cfg.family == "vlm":
        n = cfg.vision.n_patches
        p = rng.standard_normal((batch, n, cfg.d_model))
        out["patches"] = jnp.asarray(p, jnp.dtype(cfg.compute_dtype))
        out["tokens"] = out["tokens"][:, : seq - n]
    return out


def lm_stream(cfg, batch: int, seq: int, seed: int = 0,
              start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield lm_batch(cfg, step, batch, seq, seed)
        step += 1


def shard_batch(batch: dict, mesh, batch_axes):
    """Place a host batch onto the mesh, batch dim sharded over batch_axes."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def place(x):
        spec = P(batch_axes, *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(place, batch)
