"""Host-side metrics: fixed-edge histograms, counters, gauges, clocks.

Stdlib-only by design. Device code never calls into this module — the
jit-compatible half of the telemetry layer lives in :mod:`repro.obs.diag`
(static-shape aux outputs) and is *drained* into a
:class:`MetricsRegistry` host-side, after the jitted program returns.

This module is also the repo's single wall-clock site: reprolint RL007
forbids ``time.time``/``perf_counter`` everywhere else under
``src/repro/`` so that every duration the repo reports flows through
one clock (``now()``) and one recording vocabulary (the catalog names).
"""
from __future__ import annotations

import bisect
import contextlib
import time
from typing import Dict, Iterable, List, Optional, Sequence

from . import catalog as CAT

__all__ = ["now", "Histogram", "MetricsRegistry"]


def now() -> float:
    """Monotonic wall-clock read — the obs layer's only timer source."""
    return time.perf_counter()


class Histogram:
    """Fixed-edge histogram: counts per bucket + sum/count/min/max.

    Bucket ``i`` covers ``(edges[i-1], edges[i]]`` (bucket 0 is the
    underflow ``(-inf, edges[0]]``, the last bucket the overflow
    ``(edges[-1], inf)``) — the same convention as
    ``obs.diag.histogram_counts``, so jit-computed counts vectors merge
    losslessly via :meth:`merge_counts`.
    """

    __slots__ = ("edges", "counts", "sum", "count", "min", "max")

    def __init__(self, edges: Sequence[float]):
        self.edges = tuple(float(e) for e in edges)
        if list(self.edges) != sorted(set(self.edges)):
            raise ValueError("histogram edges must be strictly increasing")
        self.counts: List[int] = [0] * (len(self.edges) + 1)
        self.sum = 0.0
        self.count = 0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    # -- recording ----------------------------------------------------------

    def record(self, value: float) -> None:
        v = float(value)
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.sum += v
        self.count += 1
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def record_many(self, values: Iterable[float]) -> None:
        for v in values:
            self.record(v)

    def merge_counts(self, counts: Sequence[int], total: float,
                     n: int) -> None:
        """Drain a jit-computed counts vector (``diag.histogram_counts``
        convention: ``len(edges) + 1`` buckets) plus its sum and count.
        Min/max are only known to bucket resolution, so the extreme
        nonempty buckets' bounds stand in for them."""
        if len(counts) != len(self.counts):
            raise ValueError(
                f"counts length {len(counts)} does not match "
                f"{len(self.counts)} buckets of edges {len(self.edges)}")
        for i, c in enumerate(counts):
            self.counts[i] += int(c)
        self.sum += float(total)
        self.count += int(n)
        nz = [i for i, c in enumerate(counts) if c]
        if nz:
            lo = self.edges[nz[0] - 1] if nz[0] > 0 else self.edges[0]
            hi = (self.edges[nz[-1]] if nz[-1] < len(self.edges)
                  else self.edges[-1])
            self.min = lo if self.min is None else min(self.min, lo)
            self.max = hi if self.max is None else max(self.max, hi)

    # -- queries ------------------------------------------------------------

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def percentile(self, q: float) -> float:
        """Linear interpolation within the bucket holding rank q/100,
        with the extreme buckets clamped to the observed min/max."""
        if not self.count:
            return float("nan")
        target = q / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            if cum + c >= target:
                lo = self.edges[i - 1] if i > 0 else self.min
                hi = (self.edges[i] if i < len(self.edges) else self.max)
                if self.min is not None:
                    lo = min(max(lo, self.min), self.max)
                    hi = max(min(hi, self.max), self.min)
                frac = max(target - cum, 0.0) / c
                return lo + frac * (hi - lo)
            cum += c
        return self.max

    def snapshot(self) -> dict:
        return {
            "edges": list(self.edges),
            "counts": list(self.counts),
            "sum": self.sum,
            "count": self.count,
            "min": self.min,
            "max": self.max,
        }

    @classmethod
    def from_snapshot(cls, snap: dict) -> "Histogram":
        h = cls(snap["edges"])
        h.counts = [int(c) for c in snap["counts"]]
        h.sum = float(snap["sum"])
        h.count = int(snap["count"])
        h.min = snap.get("min")
        h.max = snap.get("max")
        return h

    def merge(self, other: "Histogram") -> None:
        if self.edges != other.edges:
            raise ValueError("cannot merge histograms with different edges")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.sum += other.sum
        self.count += other.count
        for attr, pick in (("min", min), ("max", max)):
            theirs = getattr(other, attr)
            if theirs is not None:
                mine = getattr(self, attr)
                setattr(self, attr,
                        theirs if mine is None else pick(mine, theirs))


class MetricsRegistry:
    """Counters, gauges and histograms keyed by catalog names.

    The host-side accumulation point of the telemetry layer: jitted code
    emits static-shape aux outputs, host code drains them here; sinks
    (:mod:`repro.obs.sinks`) serialize :meth:`snapshot` to JSONL /
    Prometheus text. Unknown names are accepted (the catalog documents,
    the docs CI enforces); histogram edges default to the catalog entry
    for the name.
    """

    def __init__(self):
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, Histogram] = {}

    # -- recording ----------------------------------------------------------

    def counter(self, name: str, n: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + float(n)

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def histogram(self, name: str,
                  edges: Optional[Sequence[float]] = None) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(
                edges if edges is not None else CAT.default_edges(name))
        return h

    def observe(self, name: str, value: float) -> None:
        self.histogram(name).record(value)

    @contextlib.contextmanager
    def timer(self, name: str, kind: str = "histogram"):
        """Time a block into ``name`` (histogram sample or gauge set)."""
        t0 = now()
        try:
            yield
        finally:
            dt = now() - t0
            if kind == "gauge":
                self.gauge(name, dt)
            else:
                self.observe(name, dt)

    # -- export -------------------------------------------------------------

    def snapshot(self) -> dict:
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {k: h.snapshot()
                           for k, h in self.histograms.items()},
        }
