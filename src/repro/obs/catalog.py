"""Canonical metric catalog for the telemetry layer (DESIGN.md §11).

Pure data, stdlib-only — the same property :mod:`repro.lint.catalog`
keeps for the rule table: ``scripts/check_docs.py`` imports this module
to verify the DESIGN.md §11 metric-name table stays in sync with the
registered metrics, and it must be able to do so without jax.

Every metric the repo emits is registered here with its kind, unit and
(for histograms) fixed bucket edges. The names are the single shared
vocabulary: ``examples/serve.py``, ``benchmarks/serve.py``, the
scheduler and the launch dry-run all record under these names, so one
JSONL artifact (and one Prometheus exposition) carries the whole
pipeline's telemetry. A ``MetricsRegistry`` accepts unknown names — the
catalog is documentation-enforcing, not a runtime gate — but anything
the repo itself records must be listed here or the docs CI fails.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

__all__ = [
    "MetricInfo",
    "METRICS",
    "LATENCY_EDGES_S",
    "FRACTION_EDGES",
    "ROUND_EDGES",
    "default_edges",
    "info",
]


def _log_edges(decades, mantissas) -> Tuple[float, ...]:
    out = []
    for d in decades:
        for m in mantissas:
            out.append(round(m * 10.0 ** d, 12))
    return tuple(out)


# Log-spaced latency edges, 10 per decade from 10us to 100s: adjacent
# edges are <= 1.34x apart, so a within-bucket linear interpolation
# bounds the percentile error at a few tens of percent of the value —
# tight enough for the p50/p95/p99 fields in BENCH_serve.json while the
# [len(edges)+1] counts vector stays a static-shape jit aux output.
LATENCY_EDGES_S = _log_edges(
    range(-5, 2), (1.0, 1.2, 1.5, 2.0, 2.5, 3.0, 4.0, 5.0, 6.0, 8.0)
) + (100.0,)

# Replica-disagreement rates are multiples of 1/m; 1/16 steps resolve
# every realizable value up to m=16 replicas exactly.
FRACTION_EDGES = tuple(round(i / 16.0, 6) for i in range(17))

# Consensus round counts are small integers bounded by the static
# p_end (tens of rounds at eps=1e-4): exact buckets through 8, then
# ~1.4x-spaced up to the doubled-dropout regime.
ROUND_EDGES = (1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0, 12.0, 16.0, 24.0,
               32.0, 48.0, 64.0)


class MetricInfo(NamedTuple):
    name: str
    kind: str  # 'counter' | 'gauge' | 'histogram'
    unit: str
    description: str
    edges: Optional[Tuple[float, ...]] = None  # histograms only


METRICS = (
    # -- serve path (engine + scheduler boundary) ---------------------------
    MetricInfo("serve.queue_depth", "gauge", "requests",
               "Requests waiting in the scheduler FIFO after admission."),
    MetricInfo("serve.slots_active", "gauge", "slots",
               "Pool slots holding a live, partially-decoded sequence."),
    MetricInfo("serve.admitted", "counter", "requests",
               "Requests prefilled into a pool slot."),
    MetricInfo("serve.rejected", "counter", "requests",
               "Requests refused at admission (prompt + budget exceeds "
               "slot capacity)."),
    MetricInfo("serve.retired", "counter", "requests",
               "Sequences completed (EOS or token budget) and evicted."),
    MetricInfo("serve.tokens_out", "counter", "tokens",
               "Decoded tokens handed back to the host (per decode "
               "block, all active slots)."),
    MetricInfo("serve.ttft_s", "histogram", "s",
               "Time to first token: prefill + first sample, per "
               "request/batch call.", LATENCY_EDGES_S),
    MetricInfo("serve.decode_step_s", "histogram", "s",
               "Per-token decode latency (scanned block wall time / "
               "tokens in block).", LATENCY_EDGES_S),
    MetricInfo("serve.compile_s", "gauge", "s",
               "Trace + XLA compile time of the first serve call."),
    MetricInfo("serve.replica_disagreement", "histogram", "fraction",
               "Per-token fraction of decode replicas whose argmax "
               "differs from the robustly aggregated token.",
               FRACTION_EDGES),
    MetricInfo("serve.kv_bytes_per_slot", "gauge", "bytes",
               "KV-cache HBM bytes one pool slot costs (quantization "
               "scales and robust replica stacking included)."),
    # -- robust aggregation diagnostics (train path) ------------------------
    MetricInfo("agg.alpha_hat", "gauge", "fraction",
               "Online effective-alpha estimate: fraction of workers "
               "whose deviation score is flagged Byzantine."),
    MetricInfo("agg.suspected_workers", "gauge", "workers",
               "Workers flagged by the suspicion mask this step."),
    MetricInfo("agg.grad_norm_pre", "gauge", "l2",
               "Mean per-worker gradient L2 norm before aggregation."),
    MetricInfo("agg.grad_norm_post", "gauge", "l2",
               "L2 norm of the robustly aggregated gradient."),
    MetricInfo("agg.worker_weight_min", "gauge", "weight",
               "Smallest online per-worker census weight in the adaptive "
               "aggregation state (DESIGN.md §14); 1.0 means no worker "
               "is downweighted."),
    # -- decentralized consensus backend (DESIGN.md §13) --------------------
    MetricInfo("consensus.rounds", "histogram", "rounds",
               "Rounds until the honest-alive spread first reached eps "
               "(the static bound p_end when it never did).",
               ROUND_EDGES),
    MetricInfo("dist.messages_dropped", "counter", "messages",
               "Peer messages between live workers lost to injected "
               "dropout across all consensus rounds."),
    MetricInfo("dist.quorum", "gauge", "fraction",
               "Fraction of (round, live receiver) slots that met the "
               "n-f quorum; 0 means every round stalled (quorum lost)."),
    # -- training loop ------------------------------------------------------
    MetricInfo("train.step_s", "histogram", "s",
               "Wall time per training step (post-compile).",
               LATENCY_EDGES_S),
    MetricInfo("train.loss", "gauge", "nats",
               "Training loss at the last recorded step."),
    # -- launch / compile-time cost (dryrun HLO analysis) -------------------
    MetricInfo("launch.compile_flops", "gauge", "flops",
               "Trip-count-aware HLO FLOPs per chip from the dry-run "
               "cost analysis."),
    MetricInfo("launch.compile_hbm_bytes", "gauge", "bytes",
               "HBM bytes accessed per chip (dry-run HLO analysis)."),
    MetricInfo("launch.compile_collective_bytes", "gauge", "bytes",
               "Collective bytes moved per chip (dry-run HLO analysis)."),
    MetricInfo("launch.compile_peak_memory_bytes", "gauge", "bytes",
               "Compiled peak memory per chip (args + temps + outputs "
               "- aliased)."),
)

_BY_NAME = {m.name: m for m in METRICS}


def info(name: str) -> Optional[MetricInfo]:
    return _BY_NAME.get(name)


def default_edges(name: str) -> Tuple[float, ...]:
    """Bucket edges for a histogram metric: its registered edges, or the
    latency grid for names outside the catalog."""
    m = _BY_NAME.get(name)
    if m is not None and m.edges is not None:
        return m.edges
    return LATENCY_EDGES_S
