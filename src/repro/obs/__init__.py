"""``repro.obs``: jit-safe telemetry for the robust train/serve paths.

Layout (DESIGN.md §11):

* :mod:`~repro.obs.catalog` — canonical metric names, kinds and bucket
  edges (stdlib-only; the docs CI checks DESIGN.md §11 against it).
* :mod:`~repro.obs.metrics` — host-side ``MetricsRegistry``, fixed-edge
  ``Histogram`` (with percentiles), and ``now()`` — the repo's single
  wall-clock site (reprolint RL007).
* :mod:`~repro.obs.sinks`   — JSONL writer + Prometheus text exposition.
* :mod:`~repro.obs.diag`    — jit-side diagnostics (suspicion scores,
  alpha-hat, replica disagreement, histogram counts) as static-shape
  aux outputs. Imports jax.
* :mod:`~repro.obs.trace`   — profiler spans. Imports jax.

The stdlib-only half (catalog, metrics, sinks) is imported eagerly so
``repro.obs`` works in jax-less environments (docs CI, pre-commit);
the jax half loads lazily on attribute access.
"""
from __future__ import annotations

from . import catalog, metrics, sinks
from .metrics import Histogram, MetricsRegistry, now
from .sinks import JsonlSink, merge_records, prometheus_text, read_jsonl

__all__ = [
    "catalog",
    "metrics",
    "sinks",
    "diag",
    "trace",
    "Histogram",
    "MetricsRegistry",
    "now",
    "JsonlSink",
    "read_jsonl",
    "merge_records",
    "prometheus_text",
    "AggDiagnostics",
    "trace_span",
    "named_span",
]

_LAZY = {
    "diag": (".diag", None),
    "trace": (".trace", None),
    "AggDiagnostics": (".diag", "AggDiagnostics"),
    "trace_span": (".trace", "trace_span"),
    "named_span": (".trace", "named_span"),
}


def __getattr__(name):
    entry = _LAZY.get(name)
    if entry is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    mod = importlib.import_module(entry[0], __name__)
    obj = mod if entry[1] is None else getattr(mod, entry[1])
    globals()[name] = obj
    return obj
