"""Metrics sinks: JSONL records and Prometheus text exposition.

Stdlib-only. One JSONL artifact carries the whole pipeline's telemetry:
runtime records (``kind: "metrics"`` — a registry snapshot plus meta)
and compile-time records (``kind: "dryrun"`` — the launch dry-run's HLO
cost summary as gauges, with the full result dict attached for
``launch/report.py``). ``scripts/metrics_dump.py`` merges a JSONL file
back into one summary and renders it as Prometheus text.

Wire format (one JSON object per line):

    {"kind": "metrics", "counters": {...}, "gauges": {...},
     "histograms": {name: {edges, counts, sum, count, min, max}},
     "meta": {...}}
"""
from __future__ import annotations

import json
from typing import IO, Iterable, List, Optional

from .metrics import Histogram, MetricsRegistry

__all__ = [
    "JsonlSink",
    "read_jsonl",
    "merge_records",
    "prometheus_text",
]


class JsonlSink:
    """Append-mode JSONL writer (context manager)."""

    def __init__(self, path: str, append: bool = True):
        self.path = path
        self._f: Optional[IO[str]] = open(path, "a" if append else "w")

    def write(self, record: dict) -> None:
        self._f.write(json.dumps(record) + "\n")
        self._f.flush()

    def write_registry(self, reg: MetricsRegistry, **meta) -> None:
        rec = {"kind": "metrics", **reg.snapshot()}
        if meta:
            rec["meta"] = meta
        self.write(rec)

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_jsonl(path: str) -> List[dict]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def merge_records(records: Iterable[dict]) -> dict:
    """Fold JSONL records into one summary: counters sum, gauges take
    the last value, histograms merge (matching edges required)."""
    counters: dict = {}
    gauges: dict = {}
    hists: dict = {}
    for rec in records:
        for k, v in rec.get("counters", {}).items():
            counters[k] = counters.get(k, 0.0) + float(v)
        for k, v in rec.get("gauges", {}).items():
            gauges[k] = float(v)
        for k, snap in rec.get("histograms", {}).items():
            h = Histogram.from_snapshot(snap)
            if k in hists:
                hists[k].merge(h)
            else:
                hists[k] = h
    return {"counters": counters, "gauges": gauges,
            "histograms": {k: h.snapshot() for k, h in hists.items()}}


def _prom_name(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


def _fmt(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


def prometheus_text(summary: dict) -> str:
    """Prometheus text exposition of a merged summary (or a single
    registry snapshot — same schema)."""
    lines: List[str] = []
    for name in sorted(summary.get("counters", {})):
        pn = _prom_name(name)
        # classic text format: the TYPE line must name the sample family
        # (_total included), or strict parsers treat it as untyped
        lines.append(f"# TYPE {pn}_total counter")
        lines.append(f"{pn}_total {_fmt(summary['counters'][name])}")
    for name in sorted(summary.get("gauges", {})):
        pn = _prom_name(name)
        lines.append(f"# TYPE {pn} gauge")
        lines.append(f"{pn} {_fmt(summary['gauges'][name])}")
    for name in sorted(summary.get("histograms", {})):
        snap = summary["histograms"][name]
        pn = _prom_name(name)
        lines.append(f"# TYPE {pn} histogram")
        cum = 0
        edges, counts = snap["edges"], snap["counts"]
        for e, c in zip(edges, counts[:-1]):
            cum += int(c)
            lines.append(f'{pn}_bucket{{le="{_fmt(e)}"}} {cum}')
        cum += int(counts[-1])
        lines.append(f'{pn}_bucket{{le="+Inf"}} {cum}')
        lines.append(f"{pn}_sum {_fmt(snap['sum'])}")
        lines.append(f"{pn}_count {int(snap['count'])}")
    return "\n".join(lines) + "\n"
