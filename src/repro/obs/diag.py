"""jit-side aggregation diagnostics: static-shape aux outputs.

The per-worker deviation statistics the robust aggregation layer
computes and throws away are exactly the signals the ROADMAP's
adaptive-aggregation item needs (Yin et al. 2018's detection-style
analysis and ROSE's residual tests both reduce to them). This module
recovers them as a fixed-shape :class:`AggDiagnostics` aux output that
rides any jitted program — no host callbacks, no data-dependent shapes:

* ``scores[w]``    — L2 deviation of worker ``w``'s row from the robust
  aggregate, summed over every leaf of the gradient pytree.
* ``suspected[w]`` — robust z-score outlier mask over the scores: MAD-
  scaled (``core.vrmom.mad_scale``, the paper's own scale estimator)
  with a relative floor so the all-honest regime — scores tightly
  concentrated, MAD ≈ 0 — stays all-false instead of amplifying float
  jitter into accusations. Identical honest rows (the serve replicas'
  deterministic forward) give score 0 exactly and an all-false mask.
* ``alpha_hat``    — fraction suspected: the online effective-alpha
  estimate.
* ``pre_norms[w]`` / ``post_norm`` — per-worker gradient norms before
  aggregation and the norm of the aggregate.

``histogram_counts`` is the jit-side half of the fixed-edge histogram
convention (bucket ``i`` = ``(edges[i-1], edges[i]]``): the counts
vector is a static ``[len(edges)+1]`` aux output that
``obs.metrics.Histogram.merge_counts`` drains host-side.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core.vrmom import mad_scale, mom

__all__ = [
    "AggDiagnostics",
    "finalize_diag",
    "diagnose",
    "tree_diagnose",
    "replica_disagreement",
    "histogram_counts",
    "ServeDiag",
    "serve_diag",
]

# Suspicion threshold on the robust z-score. The denominator carries a
# 5% relative floor, so a worker is flagged only when its deviation
# score exceeds the median score by > 4 MAD-sigmas AND by > 20% of the
# median — honest-only stacks (scores concentrated within O(1/sqrt(n))
# relative spread) never trip either arm, while any of the core/attacks
# corruptions moves the corrupted rows orders of magnitude past both.
_Z_THRESH = 4.0
_REL_FLOOR = 0.05


class AggDiagnostics(NamedTuple):
    """Static-shape per-step aggregation diagnostics (W = worker count)."""

    scores: jax.Array     # [W] f32 — L2 deviation from the aggregate
    suspected: jax.Array  # [W] bool — robust-outlier mask
    alpha_hat: jax.Array  # []  f32 — fraction suspected
    pre_norms: jax.Array  # [W] f32 — per-worker gradient L2 norms
    post_norm: jax.Array  # []  f32 — aggregate gradient L2 norm


def finalize_diag(dev_sq, pre_sq, post_sq) -> AggDiagnostics:
    """Deviation/norm second moments -> AggDiagnostics (all f32)."""
    dev = jnp.sqrt(dev_sq.astype(jnp.float32))
    center = mom(dev, axis=0)
    scale = mad_scale(dev, axis=0, center=center)
    z = (dev - center) / (scale + _REL_FLOOR * center + 1e-12)
    suspected = z > _Z_THRESH
    return AggDiagnostics(
        scores=dev,
        suspected=suspected,
        alpha_hat=jnp.mean(suspected.astype(jnp.float32)),
        pre_norms=jnp.sqrt(pre_sq.astype(jnp.float32)),
        post_norm=jnp.sqrt(post_sq.astype(jnp.float32)),
    )


def diagnose(x, agg, axis: int = 0) -> AggDiagnostics:
    """Diagnostics for one stacked array ``x`` ([.., W, ..] over
    ``axis``) against its aggregate ``agg`` (x minus the worker dim)."""
    if axis != 0:
        x = jnp.moveaxis(x, axis, 0)
    w = x.shape[0]
    xf = x.reshape(w, -1).astype(jnp.float32)
    af = agg.reshape(-1).astype(jnp.float32)
    dev_sq = jnp.sum(jnp.square(xf - af[None]), axis=1)
    pre_sq = jnp.sum(jnp.square(xf), axis=1)
    post_sq = jnp.sum(jnp.square(af))
    return finalize_diag(dev_sq, pre_sq, post_sq)


def tree_diagnose(stacked, agg) -> AggDiagnostics:
    """Diagnostics for a stacked-gradient pytree (leaves ``[W, ...]``)
    against the aggregated pytree, accumulating the second moments
    leaf-by-leaf — no second stacked copy is materialized, and under
    GSPMD the per-leaf sums reduce over however the leaves are sharded.
    """
    sl = jax.tree.leaves(stacked)
    al = jax.tree.leaves(agg)
    w = sl[0].shape[0]
    dev_sq = jnp.zeros((w,), jnp.float32)
    pre_sq = jnp.zeros((w,), jnp.float32)
    post_sq = jnp.zeros((), jnp.float32)
    for s, a in zip(sl, al):
        sf = s.reshape(w, -1).astype(jnp.float32)
        af = a.reshape(-1).astype(jnp.float32)
        dev_sq += jnp.sum(jnp.square(sf - af[None]), axis=1)
        pre_sq += jnp.sum(jnp.square(sf), axis=1)
        post_sq += jnp.sum(jnp.square(af))
    return finalize_diag(dev_sq, pre_sq, post_sq)


def replica_disagreement(logits_r, agg) -> jax.Array:
    """[m, B, V] replica logits + [B, V] aggregate -> [B] f32 fraction
    of replicas whose argmax differs from the aggregated token — the
    serve path's live Byzantine detector."""
    rep_tok = jnp.argmax(logits_r, axis=-1)           # [m, B]
    agg_tok = jnp.argmax(agg, axis=-1)                # [B]
    return jnp.mean((rep_tok != agg_tok[None]).astype(jnp.float32), axis=0)


def histogram_counts(x, edges: Sequence[float],
                     mask=None) -> jax.Array:
    """Fixed-edge histogram counts of ``x`` (any shape, raveled) as a
    static ``[len(edges)+1]`` int32 vector; ``edges`` must be a static
    (hashable) sequence. Bucket ``i`` covers ``(edges[i-1], edges[i]]``
    — identical to ``obs.metrics.Histogram``, so the counts drain via
    ``Histogram.merge_counts`` with no rebinning. ``mask`` (bool,
    broadcastable to ``x``) excludes entries without changing the static
    shape — masked-out values simply contribute 0 to their bucket."""
    e = jnp.asarray(tuple(edges), jnp.float32)
    idx = jnp.searchsorted(e, x.astype(jnp.float32).ravel(), side="left")
    w = (jnp.ones(idx.shape, jnp.int32) if mask is None
         else jnp.broadcast_to(mask, jnp.shape(x)).ravel().astype(jnp.int32))
    return jnp.zeros((len(tuple(edges)) + 1,), jnp.int32).at[idx].add(w)


class ServeDiag(NamedTuple):
    """Static-shape serve-loop diagnostics aux: a fixed-edge counts
    vector over the per-token replica-disagreement rates plus their sum
    (count = number of rates is static host-side knowledge)."""

    counts: jax.Array  # [len(FRACTION_EDGES)+1] int32
    total: jax.Array   # [] f32 — sum of the rates


def serve_diag(rates, edges: Tuple[float, ...], mask=None) -> ServeDiag:
    """``mask`` (bool, broadcastable to ``rates``) restricts the
    histogram to live entries — the pool path passes the active-slot
    mask so inactive slots decoding stale caches do not dilute the
    per-request Byzantine signal."""
    r = rates.astype(jnp.float32)
    if mask is not None:
        r = r * jnp.broadcast_to(mask, r.shape).astype(jnp.float32)
    return ServeDiag(counts=histogram_counts(rates, edges, mask=mask),
                     total=jnp.sum(r))
