"""Profiler spans: name the subsystems in ``jax.profiler`` traces.

Two helpers for the two sides of the jit boundary:

* ``trace_span(name)`` — host-side wall-clock span
  (``jax.profiler.TraceAnnotation``): wraps dispatch + blocking work so
  the profiler timeline attributes host time per subsystem.
* ``named_span(name)`` — in-trace annotation (``jax.named_scope``):
  names the ops staged out while it is active, so the compiled HLO (and
  the device-side profile) carries the subsystem name. Zero runtime
  cost — it only decorates metadata at trace time.

The repo's hot paths are pre-annotated with the DESIGN.md §11 span
names: ``rrs.all_to_all`` (the robust-reduce wire), ``kernels.aggregate``
(the fused Pallas aggregation family), ``kernels.decode_attention``,
``serve.decode_scan`` (the engine's fused decode loop), and
``consensus.round_loop`` (the §13 peer-to-peer round iteration).
"""
from __future__ import annotations

import contextlib

import jax

__all__ = ["trace_span", "named_span"]


@contextlib.contextmanager
def trace_span(name: str):
    """Host-side profiler span (shows up in jax.profiler traces)."""
    with jax.profiler.TraceAnnotation(name):
        yield


def named_span(name: str):
    """In-trace scope: names the ops staged under it (jax.named_scope)."""
    return jax.named_scope(name)
