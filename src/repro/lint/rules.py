"""Layer-1 AST rules (RL001–RL006, DESIGN.md §10).

Each rule is a small class with an ``applies(relpath)`` path filter and
a ``check(tree, src, relpath)`` generator of :class:`Finding`s. Rules
are conservative by construction: they flag only patterns that are
unambiguous in the AST (a direct ``jnp.median`` call, a ``jnp.repeat``
of a K/V-named tensor, a bare traced parameter in an ``if`` test) and
leave the gray zone to the layer-2 trace auditor. The price is missed
transitive cases; the payoff is a tree that can be lint-clean with zero
unexplained suppressions.

Everything here is stdlib-only — the AST layer must run in an
environment without jax (pre-commit, docs CI).
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from .catalog import info
from .findings import Finding

__all__ = ["Rule", "RULES", "rule_ids"]


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

def _build_parents(tree: ast.AST) -> Dict[ast.AST, ast.AST]:
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression ('jax.jit', 'pl.BlockSpec')."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def _is_jit(node: ast.AST) -> bool:
    """Does this expression denote jax.jit (or a partial application)?"""
    d = _dotted(node)
    if d in ("jax.jit", "jit"):
        return True
    if isinstance(node, ast.Call) and _dotted(node.func) in (
            "functools.partial", "partial"):
        return bool(node.args) and _is_jit(node.args[0])
    return False


def _static_names(call: Optional[ast.Call]) -> Tuple[Set[str], Set[int]]:
    """static_argnames / static_argnums constants of a jit(...) call."""
    names: Set[str] = set()
    nums: Set[int] = set()
    if call is None:
        return names, nums
    for kw in call.keywords:
        vals: List[ast.expr]
        if isinstance(kw.value, (ast.Tuple, ast.List)):
            vals = list(kw.value.elts)
        else:
            vals = [kw.value]
        if kw.arg == "static_argnames":
            names |= {v.value for v in vals
                      if isinstance(v, ast.Constant) and isinstance(v.value, str)}
        elif kw.arg == "static_argnums":
            nums |= {v.value for v in vals
                     if isinstance(v, ast.Constant) and isinstance(v.value, int)}
    return names, nums


class Rule:
    """Base: subclasses set ``id`` and implement ``check``."""

    id: str = ""

    @property
    def name(self) -> str:
        return info(self.id).name

    def applies(self, relpath: str) -> bool:
        return True

    def check(self, tree: ast.AST, src: str,
              relpath: str) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, relpath: str, line: int, message: str) -> Finding:
        return Finding(rule_id=self.id, path=relpath, line=line,
                       message=message)


# ---------------------------------------------------------------------------
# RL001 — robust aggregation must route through core/estimator
# ---------------------------------------------------------------------------

class DirectAggregationRule(Rule):
    """DESIGN §7: the Estimator layer is the single dispatch site. A
    call site computing ``jnp.median`` over a worker/replica stack, or
    reaching into ``core.aggregators`` directly, silently bypasses
    backend dispatch, trace-time validation (trimmed_mean beta, the
    coordinatewise gate) and the fused kernel."""

    id = "RL001"

    # The estimator layer itself plus its numerical oracles.
    ALLOW = (
        "core/estimator.py",
        "core/aggregators.py",
        "core/adaptive.py",
        "core/vrmom.py",
        "core/__init__.py",
        "kernels/ref.py",
        "kernels/vrmom.py",
    )
    _AGG_FNS = ("median", "nanmedian", "quantile", "nanquantile",
                "percentile", "nanpercentile")
    _AGG_MODULE_ALIASES = ("aggregators", "_A", "_agg", "AGG")

    def applies(self, relpath: str) -> bool:
        return not relpath.endswith(self.ALLOW)

    def check(self, tree, src, relpath):
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                d = _dotted(node.func)
                mod, _, attr = d.rpartition(".")
                if attr in self._AGG_FNS and mod in ("jnp", "jax.numpy"):
                    yield self.finding(
                        relpath, node.lineno,
                        f"direct `{d}` call bypasses the Estimator "
                        f"dispatch layer (core/estimator, DESIGN §7); "
                        f"use Estimator(method=...).apply(x, axis)")
                elif mod in self._AGG_MODULE_ALIASES:
                    yield self.finding(
                        relpath, node.lineno,
                        f"direct `{d}` call bypasses the Estimator "
                        f"dispatch layer; aggregator functions must "
                        f"not be called outside core/estimator")
            elif isinstance(node, ast.ImportFrom):
                module = node.module or ""
                if module.endswith("aggregators"):
                    yield self.finding(
                        relpath, node.lineno,
                        "importing from core.aggregators outside the "
                        "estimator layer — route through "
                        "core.estimator.Estimator instead")
                elif any(a.name == "aggregators" for a in node.names):
                    yield self.finding(
                        relpath, node.lineno,
                        "importing core.aggregators outside the "
                        "estimator layer — route through "
                        "core.estimator.Estimator instead")


# ---------------------------------------------------------------------------
# RL002 — no jnp.repeat of K/V head dims in models/ and kernels/
# ---------------------------------------------------------------------------

class KVRepeatRule(Rule):
    """DESIGN §8: GQA is computed grouped; repeating K/V to the query
    head count multiplies cache read traffic by H/Hkv. Name-based on the
    repeated tensor (k/v/cache.k/...) so SSM state-group expansion in
    mamba2 (different invariant, no KV cache) is not dragged in."""

    id = "RL002"

    _KV_NAMES = frozenset((
        "k", "v", "ck", "cv", "kf", "vf", "kk", "vv", "k2", "v2",
        "key", "value", "keys", "values", "k_cache", "v_cache",
    ))

    def applies(self, relpath: str) -> bool:
        return "models/" in relpath or "kernels/" in relpath

    def _kv_name(self, node: ast.expr) -> Optional[str]:
        if isinstance(node, ast.Name) and node.id.lower() in self._KV_NAMES:
            return node.id
        if isinstance(node, ast.Attribute) and \
                node.attr.lower() in self._KV_NAMES:
            return _dotted(node)
        return None

    def check(self, tree, src, relpath):
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            if d not in ("jnp.repeat", "jax.numpy.repeat"):
                continue
            if not node.args:
                continue
            name = self._kv_name(node.args[0])
            if name is not None:
                yield self.finding(
                    relpath, node.lineno,
                    f"`jnp.repeat({name}, ...)` materializes K/V at the "
                    f"query-head count — GQA must stay grouped "
                    f"(kernels/decode_attention discipline, DESIGN §8)")


# ---------------------------------------------------------------------------
# RL003 — no Python branching / casts on traced jit parameters
# ---------------------------------------------------------------------------

class TraceUnsafePythonRule(Rule):
    """A Python ``if``/``while`` on a traced value raises
    TracerBoolConversionError at best and bakes a stale branch into the
    jaxpr at worst; ``int()``/``float()`` force a device sync or fail.
    Conservative scope: only functions that are *directly* jitted
    (decorated with jax.jit / functools.partial(jax.jit, ...) or passed
    by name to a jax.jit(...) call in the same file), only bare uses of
    their non-static parameters. ``.shape``/``.ndim``/``.dtype``/
    ``.size`` reads and ``is None`` tests are static and exempt."""

    id = "RL003"

    _STATIC_ATTRS = frozenset(("shape", "ndim", "dtype", "size", "aval",
                               "sharding"))
    _CASTS = frozenset(("int", "float", "bool"))

    # -- collect jitted functions ------------------------------------------

    def _jitted_functions(self, tree) -> List[Tuple[ast.FunctionDef,
                                                    Set[str]]]:
        defs: Dict[str, List[ast.FunctionDef]] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, []).append(node)

        out: List[Tuple[ast.FunctionDef, Set[str]]] = []

        def traced_params(fn, static_names, static_nums):
            params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
            traced = set()
            for i, p in enumerate(params):
                if p in static_names or i in static_nums or p == "self":
                    continue
                traced.add(p)
            return traced

        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if _is_jit(dec):
                        call = dec if isinstance(dec, ast.Call) else None
                        names, nums = _static_names(call)
                        out.append((node, traced_params(node, names, nums)))
            elif isinstance(node, ast.Call) and _is_jit(node.func) \
                    and node.args and isinstance(node.args[0], ast.Name):
                names, nums = _static_names(node)
                for fn in defs.get(node.args[0].id, ()):
                    out.append((fn, traced_params(fn, names, nums)))
        return out

    # -- offending-name detection ------------------------------------------

    def _offending(self, expr: ast.expr, traced: Set[str]) -> Optional[str]:
        """First traced parameter referenced outside a static-attr read."""

        def walk(node) -> Optional[str]:
            if isinstance(node, ast.Attribute):
                if node.attr in self._STATIC_ATTRS:
                    return None  # x.shape[...] etc. — static under jit
                return walk(node.value)
            if isinstance(node, ast.Name):
                return node.id if node.id in traced else None
            if isinstance(node, ast.Call):
                # len(x.shape) fine; isinstance(x, T) fine
                if _dotted(node.func) in ("len", "isinstance", "getattr",
                                          "hasattr", "type"):
                    return None
                hit = walk(node.func)
                if hit:
                    return hit
                for a in node.args:
                    hit = walk(a)
                    if hit:
                        return hit
                for kw in node.keywords:
                    hit = walk(kw.value)
                    if hit:
                        return hit
                return None
            if isinstance(node, ast.Compare):
                if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                    return None  # `x is None` — identity, not value
            for child in ast.iter_child_nodes(node):
                hit = walk(child)
                if hit:
                    return hit
            return None

        return walk(expr)

    def check(self, tree, src, relpath):
        seen: Set[Tuple[int, str]] = set()
        for fn, traced in self._jitted_functions(tree):
            if not traced:
                continue
            for node in ast.walk(fn):
                if isinstance(node, (ast.If, ast.While)):
                    hit = self._offending(node.test, traced)
                    if hit and (node.lineno, hit) not in seen:
                        seen.add((node.lineno, hit))
                        kind = ("while" if isinstance(node, ast.While)
                                else "if")
                        yield self.finding(
                            relpath, node.lineno,
                            f"Python `{kind}` on `{hit}`, a traced "
                            f"parameter of jitted `{fn.name}` — use "
                            f"lax.cond/jnp.where or make it static")
                elif isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Name) and \
                        node.func.id in self._CASTS:
                    for a in node.args:
                        hit = self._offending(a, traced)
                        if hit and (node.lineno, hit) not in seen:
                            seen.add((node.lineno, hit))
                            yield self.finding(
                                relpath, node.lineno,
                                f"`{node.func.id}()` cast of `{hit}`, a "
                                f"traced parameter of jitted "
                                f"`{fn.name}` — forces a host sync / "
                                f"fails under jit")


# ---------------------------------------------------------------------------
# RL004 — config-like statics must be hashable
# ---------------------------------------------------------------------------

class UnhashableStaticRule(Rule):
    """Specs used as jit static arguments key the trace cache by
    hash/eq. An unfrozen dataclass is unhashable (TypeError at the jit
    boundary); a hashable spec with a list/dict field hashes by content
    that can mutate — both are retrace hazards. Name-scoped to
    config-like classes so host-side mutable records (scheduler
    bookkeeping, cost tables) stay legal."""

    id = "RL004"

    _CONFIG_NAME = re.compile(r"(Config|Spec|Specs|Estimator|Sampling|Setup)$")
    _MUTABLE_TYPES = frozenset((
        "list", "dict", "set", "List", "Dict", "Set", "MutableMapping",
        "bytearray", "ndarray", "Array",
    ))

    def _dataclass_dec(self, cls: ast.ClassDef) -> Optional[ast.expr]:
        for dec in cls.decorator_list:
            d = _dotted(dec.func if isinstance(dec, ast.Call) else dec)
            if d in ("dataclass", "dataclasses.dataclass"):
                return dec
        return None

    def _is_frozen(self, dec: ast.expr) -> bool:
        if not isinstance(dec, ast.Call):
            return False
        return any(kw.arg == "frozen" and
                   isinstance(kw.value, ast.Constant) and kw.value.value is True
                   for kw in dec.keywords)

    def _is_namedtuple(self, cls: ast.ClassDef) -> bool:
        return any(_dotted(b) in ("NamedTuple", "typing.NamedTuple")
                   for b in cls.bases)

    def _mutable_ann(self, ann: ast.expr) -> Optional[str]:
        for node in ast.walk(ann):
            if isinstance(node, ast.Name) and node.id in self._MUTABLE_TYPES:
                return node.id
            if isinstance(node, ast.Attribute) and \
                    node.attr in self._MUTABLE_TYPES:
                return node.attr
        return None

    def check(self, tree, src, relpath):
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            if not self._CONFIG_NAME.search(node.name):
                continue
            dec = self._dataclass_dec(node)
            hashable_spec = self._is_namedtuple(node) or (
                dec is not None and self._is_frozen(dec))
            if dec is not None and not self._is_frozen(dec):
                yield self.finding(
                    relpath, node.lineno,
                    f"config-like dataclass `{node.name}` is not "
                    f"frozen=True: unhashable, so it cannot key a jit "
                    f"trace cache (retrace hazard, DESIGN §7)")
            if hashable_spec or dec is not None:
                for stmt in node.body:
                    if isinstance(stmt, ast.AnnAssign):
                        bad = self._mutable_ann(stmt.annotation)
                        if bad:
                            field = (stmt.target.id
                                     if isinstance(stmt.target, ast.Name)
                                     else "<field>")
                            yield self.finding(
                                relpath, stmt.lineno,
                                f"`{node.name}.{field}` is typed "
                                f"`{bad}` — unhashable field in a "
                                f"static spec (retrace hazard); use a "
                                f"tuple / frozen type")


# ---------------------------------------------------------------------------
# RL005 — Pallas BlockSpec index maps must be pure
# ---------------------------------------------------------------------------

class IndexMapPurityRule(Rule):
    """An index map runs at grid-scheduling time: anything beyond
    arithmetic on the grid indices (calls, attribute reads, subscripts
    into captured state) is either miscompiled or a hidden host
    dependency. Pure = names, constants, arithmetic, tuples."""

    id = "RL005"

    _IMPURE = (ast.Call, ast.Attribute, ast.Subscript, ast.Await,
               ast.NamedExpr, ast.ListComp, ast.SetComp, ast.DictComp,
               ast.GeneratorExp)

    def applies(self, relpath: str) -> bool:
        return True  # cheap: only fires on files that call BlockSpec

    def _index_map(self, call: ast.Call) -> Optional[ast.expr]:
        for kw in call.keywords:
            if kw.arg == "index_map":
                return kw.value
        if len(call.args) >= 2:
            return call.args[1]
        return None

    def check(self, tree, src, relpath):
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            if not d.endswith("BlockSpec"):
                continue
            imap = self._index_map(node)
            if not isinstance(imap, ast.Lambda):
                continue
            for sub in ast.walk(imap.body):
                if isinstance(sub, self._IMPURE):
                    yield self.finding(
                        relpath, imap.lineno,
                        f"BlockSpec index map contains "
                        f"{type(sub).__name__} — index maps must be "
                        f"pure arithmetic over the grid indices")
                    break


# ---------------------------------------------------------------------------
# RL006 — padded tile loads need an in-kernel validity mask
# ---------------------------------------------------------------------------

class UnmaskedPaddedLoadRule(Rule):
    """If the wrapper pads operands to tile boundaries (jnp.pad before
    pl.pallas_call), the kernel sees fabricated rows/keys; the flash /
    decode-attention discipline (DESIGN §8) is that validity is masked
    *in-kernel* (jnp.where over a broadcasted_iota position, or an
    explicitly inert pad value). A kernel with padded inputs and no
    masking construct is flagged. The mask may live in a same-module
    helper the kernel calls (kernel families sharing an epilogue, e.g.
    ``vrmom._agg_block``) — the scan follows direct calls to
    module-level functions."""

    id = "RL006"

    def _kernel_name(self, arg: ast.expr) -> Optional[str]:
        if isinstance(arg, ast.Name):
            return arg.id
        if isinstance(arg, ast.Call) and _dotted(arg.func) in (
                "functools.partial", "partial") and arg.args and \
                isinstance(arg.args[0], ast.Name):
            return arg.args[0].id
        return None

    def _has_mask(self, fn: ast.AST, defs=None, seen=None) -> bool:
        seen = set() if seen is None else seen
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                d = _dotted(node.func)
                if d.endswith(".where") or d.endswith("broadcasted_iota") \
                        or d == "where":
                    return True
                # masking via a shared same-module helper counts: follow
                # plain-name calls to module-level defs (one pass each)
                if defs and d in defs and d not in seen:
                    seen.add(d)
                    if self._has_mask(defs[d], defs, seen):
                        return True
        return False

    def check(self, tree, src, relpath):
        defs: Dict[str, ast.FunctionDef] = {}
        parents = None
        for node in ast.walk(tree):
            if isinstance(node, ast.FunctionDef):
                defs[node.name] = node
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call) and
                    _dotted(node.func).endswith("pallas_call")):
                continue
            # kernel fn: first arg of pallas_call (maybe partial-wrapped),
            # or a local name bound to such a partial just above.
            kname = self._kernel_name(node.args[0]) if node.args else None
            if parents is None:
                parents = _build_parents(tree)
            enclosing = node
            while enclosing in parents and not isinstance(
                    enclosing, (ast.FunctionDef, ast.AsyncFunctionDef)):
                enclosing = parents[enclosing]
            if not isinstance(enclosing, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)):
                continue
            if kname is not None and kname not in defs:
                # kernel may be a local alias: kernel = partial(_k, ...)
                for stmt in ast.walk(enclosing):
                    if isinstance(stmt, ast.Assign) and \
                            len(stmt.targets) == 1 and \
                            isinstance(stmt.targets[0], ast.Name) and \
                            stmt.targets[0].id == kname:
                        inner = self._kernel_name(stmt.value)
                        if inner:
                            kname = inner
                        break
            kernel = defs.get(kname) if kname else None
            pads = any(isinstance(n, ast.Call) and
                       _dotted(n.func).endswith(".pad")
                       for n in ast.walk(enclosing))
            if not pads or kernel is None:
                continue
            if not self._has_mask(kernel, defs):
                yield self.finding(
                    relpath, node.lineno,
                    f"pallas_call kernel `{kernel.name}` receives "
                    f"padded operands (jnp.pad in `{enclosing.name}`) "
                    f"but contains no validity mask "
                    f"(jnp.where/broadcasted_iota) — padded lanes leak "
                    f"into the result (DESIGN §8 mask discipline)")


# ---------------------------------------------------------------------------
# RL007 — wall-clock reads route through the obs layer
# ---------------------------------------------------------------------------

class WallClockOutsideObsRule(Rule):
    """DESIGN §11: ``obs.metrics.now()`` is the library's single
    wall-clock site. A stray ``time.time()``/``perf_counter()`` in
    library code is either dead telemetry (not drained into any
    registry/sink) or — worse — a host sync hiding inside a jit-adjacent
    path that no profiler span will attribute. Scoped to ``src/repro/``
    (scripts, benchmarks and tests time things however they like);
    the obs layer itself is the one allowed caller."""

    id = "RL007"

    _CLOCK_FNS = frozenset(("time", "perf_counter", "monotonic",
                            "process_time", "perf_counter_ns",
                            "monotonic_ns", "time_ns"))

    def applies(self, relpath: str) -> bool:
        return ("src/repro/" in relpath or relpath.startswith("repro/")) \
            and "/obs/" not in relpath

    def check(self, tree, src, relpath):
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                d = _dotted(node.func)
                mod, _, attr = d.rpartition(".")
                if mod == "time" and attr in self._CLOCK_FNS:
                    yield self.finding(
                        relpath, node.lineno,
                        f"direct `{d}()` call outside the obs layer — "
                        f"library code reads the wall clock through "
                        f"repro.obs.metrics.now() so every timing "
                        f"lands in the metrics registry (DESIGN §11)")
            elif isinstance(node, ast.ImportFrom):
                if (node.module or "") == "time":
                    bad = [a.name for a in node.names
                           if a.name in self._CLOCK_FNS]
                    if bad:
                        yield self.finding(
                            relpath, node.lineno,
                            f"importing {', '.join(bad)} from time "
                            f"outside the obs layer — use "
                            f"repro.obs.metrics.now() (DESIGN §11)")


RULES: Sequence[Rule] = (
    DirectAggregationRule(),
    KVRepeatRule(),
    TraceUnsafePythonRule(),
    UnhashableStaticRule(),
    IndexMapPurityRule(),
    UnmaskedPaddedLoadRule(),
    WallClockOutsideObsRule(),
)


def rule_ids() -> Tuple[str, ...]:
    return tuple(r.id for r in RULES)
