"""reprolint — static analysis for the repro stack (DESIGN.md §10).

Two layers:

* **AST rules** (:mod:`repro.lint.rules`, RL0xx) — stdlib-only source
  checks for aggregation-dispatch bypasses, GQA K/V repeats, trace-unsafe
  Python, unhashable statics, and Pallas kernel hygiene.
* **Trace auditor** (:mod:`repro.lint.auditor`, RL2xx) — drives the
  public entry points through ``jax.eval_shape``/``jax.make_jaxpr``
  without executing, verifying wire shapes/dtypes, divisibility guards,
  the coordinatewise gate, and recompile stability.

Importing this package does **not** import jax; the auditor is pulled in
lazily so the AST layer (and ``scripts/check_docs.py``) work in minimal
environments. CLI front door: ``python scripts/reprolint.py src tests``.
"""
from .catalog import ALL_IDS, AST_RULES, AUDIT_CHECKS, RuleInfo, info
from .engine import iter_py_files, lint_file, lint_paths, lint_source
from .findings import AuditResult, Finding, Report
from .hashguard import UnhashableFieldError, check_hashable_fields
from .rules import RULES, rule_ids

__all__ = [
    "ALL_IDS", "AST_RULES", "AUDIT_CHECKS", "RuleInfo", "info",
    "iter_py_files", "lint_file", "lint_paths", "lint_source",
    "AuditResult", "Finding", "Report",
    "UnhashableFieldError", "check_hashable_fields",
    "RULES", "rule_ids",
    "run_audit",
]


def run_audit(*args, **kwargs):
    """Lazy proxy for :func:`repro.lint.auditor.run_audit` (imports jax)."""
    from .auditor import run_audit as _run
    return _run(*args, **kwargs)
