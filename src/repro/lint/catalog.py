"""Catalog of every reprolint rule and audit check (DESIGN.md §10).

Pure data, no imports beyond the stdlib: ``scripts/check_docs.py``
imports this module to verify the DESIGN.md §10 rule-ID table stays in
sync with the registered rules, and it must be able to do so in an
environment without jax. Layer-1 AST rules (RL0xx) are implemented in
:mod:`repro.lint.rules`; layer-2 trace-auditor checks (RL2xx) in
:mod:`repro.lint.auditor`. RL000 is the meta-rule guarding the waiver
mechanism itself.

Each entry records the invariant the rule protects and where that
invariant was established (DESIGN section / PR in CHANGES.md), so a
finding always points back at the design decision it enforces.
"""
from __future__ import annotations

from typing import NamedTuple

__all__ = ["RuleInfo", "AST_RULES", "AUDIT_CHECKS", "ALL_IDS", "info"]


class RuleInfo(NamedTuple):
    id: str
    name: str
    invariant: str
    established: str  # DESIGN section / PR that created the invariant


AST_RULES = (
    RuleInfo(
        "RL000", "suppression-without-reason",
        "Every `# reprolint: disable=RLxxx` waiver must carry a reason; "
        "an unexplained suppression is itself a finding.",
        "this PR (§10)"),
    RuleInfo(
        "RL001", "direct-aggregation-bypass",
        "All robust aggregation routes through the hashable "
        "core.estimator.Estimator dispatch: no direct jnp.median/"
        "quantile/percentile and no core.aggregators access at call "
        "sites outside the estimator layer itself.",
        "DESIGN §7 (PR 3)"),
    RuleInfo(
        "RL002", "kv-head-repeat",
        "GQA K/V tensors are never jnp.repeat-ed to the query-head "
        "count in models/ or kernels/ — grouped compute keeps K/V "
        "cache traffic at Hkv, not H.",
        "DESIGN §8 (PR 4)"),
    RuleInfo(
        "RL003", "trace-unsafe-python",
        "No Python `if`/`while` branching and no int()/float()/bool() "
        "casts on values that flow in as traced parameters of a jitted "
        "function (shape/ndim/dtype/size reads are static and exempt).",
        "DESIGN §1-§2 (jit discipline)"),
    RuleInfo(
        "RL004", "unhashable-static",
        "Config-like specs (\\*Config/\\*Spec/Estimator/Sampling/"
        "\\*Setup) that flow into jit static args must be hashable: "
        "dataclasses frozen=True, no list/dict/set-typed fields.",
        "DESIGN §7 (PR 3); runtime backstop this PR"),
    RuleInfo(
        "RL005", "impure-index-map",
        "Pallas BlockSpec index maps are pure arithmetic functions of "
        "the grid indices: no calls, attribute reads, or subscripts.",
        "DESIGN §7-§8 kernel discipline"),
    RuleInfo(
        "RL006", "unmasked-padded-load",
        "A Pallas kernel whose wrapper zero/inf-pads its operands to "
        "tile boundaries must mask validity in-kernel (jnp.where / "
        "broadcasted_iota), per the flash/decode-attention mask "
        "discipline.",
        "DESIGN §8 (PR 4 pad_k fix)"),
    RuleInfo(
        "RL007", "wall-clock-outside-obs",
        "Library code under src/repro/ never reads the wall clock "
        "directly (time.time/perf_counter/monotonic/...): timings "
        "route through repro.obs.metrics.now() so they land in the "
        "metrics registry instead of ad-hoc prints; the obs layer is "
        "the single allowed call site.",
        "DESIGN §11 (this PR)"),
)

AUDIT_CHECKS = (
    RuleInfo(
        "RL201", "rrs-wire-shapes",
        "aggregate_stacked_rrs preserves every leaf's shape (minus the "
        "worker dim) and dtype across the padded f32 wire, for every "
        "worker count the mesh supports.",
        "DESIGN §3 (PR 1)"),
    RuleInfo(
        "RL202", "symmetric-triangle-wire",
        "aggregate_symmetric_stacked puts exactly p(p+1)/2 upper-"
        "triangle coordinates on the wire and returns a [p, p] matrix "
        "of the input dtype.",
        "DESIGN §9 (PR 5)"),
    RuleInfo(
        "RL203", "coordinatewise-gate",
        "Whole-vector estimators (geometric_median, Krum) are rejected "
        "at trace time on every chunked/RRS/serve wire, and degenerate "
        "trimmed_mean specs raise instead of silently meaning mean.",
        "DESIGN §7 (PR 3)"),
    RuleInfo(
        "RL204", "wire-dtype-discipline",
        "Robust aggregation of a bf16 gradient stack returns bf16 "
        "(f32 internally, no silent upcast of the output); robust "
        "decode logits are exactly f32.",
        "DESIGN §3/§6"),
    RuleInfo(
        "RL205", "worker-divisibility-guard",
        "robust_dot and the inloop train step refuse (at trace time) "
        "batches the worker count does not divide, instead of "
        "degrading to a non-robust grouping.",
        "DESIGN §2 (PR 1)"),
    RuleInfo(
        "RL206", "train-step-traces",
        "make_train_step's step function traces abstractly end-to-end "
        "(params/opt-state/loss shapes stable) on the config matrix.",
        "DESIGN §1 (PR 1)"),
    RuleInfo(
        "RL207", "serve-cache-roundtrip",
        "ServeEngine prefill and the scanned (robust) decode loop "
        "trace abstractly, and the pool cache tree returns with "
        "bit-identical structure/shapes/dtypes (the stacked<->flat "
        "replica layout round-trip is lossless).",
        "DESIGN §6-§7 (PR 2/3)"),
    RuleInfo(
        "RL208", "sandwich-ci-shapes",
        "The plug-in sandwich CI path (machine stats -> robust moments "
        "-> Theorem-4 factor -> intervals) traces abstractly with "
        "[p]-shaped intervals and [p, p] covariance.",
        "DESIGN §9 (PR 5)"),
    RuleInfo(
        "RL209", "recompile-stability",
        "Calling a jitted entry point twice with equal-valued but "
        "freshly constructed static configs (Estimator, ArchConfig, "
        "RobustDecodeConfig, Sampling) traces exactly once: hash/eq "
        "drift in a spec would silently retrace per call.",
        "DESIGN §7 (PR 3); guard this PR"),
    RuleInfo(
        "RL210", "consensus-wire",
        "aggregate_stacked_consensus preserves every leaf's shape and "
        "dtype through the static round loop (fault-free and faulty "
        "plans, scalar aux), and refuses n <= 5f configurations at "
        "trace time — outside that region approximate consensus loses "
        "validity.",
        "DESIGN §13 (PR 9)"),
    RuleInfo(
        "RL211", "adaptive-state-carry",
        "The adaptive aggregation state (per-worker weights, momentum, "
        "alpha_hat) is an explicit jit-pure carry: init_state/apply "
        "round-trip under eval_shape with fixed shapes and dtypes, "
        "repro.core.adaptive holds no mutable module-level state, and "
        "non-adaptive estimators refuse to mint a carry.",
        "DESIGN §14 (PR 10)"),
)

ALL_IDS = tuple(r.id for r in AST_RULES + AUDIT_CHECKS)

_BY_ID = {r.id: r for r in AST_RULES + AUDIT_CHECKS}


def info(rule_id: str) -> RuleInfo:
    return _BY_ID[rule_id]
