"""Layer-1 driver: file walking, waiver parsing, rule dispatch.

Waiver syntax (RL000): a finding on line L is waived by a comment on
line L or L-1 of the form::

    # reprolint: disable=RL002 DESIGN §5 — repeat keeps the head axis shardable

The reason after the rule list is mandatory; a bare ``disable=RL002``
produces an RL000 finding instead of a waiver. This layer is
stdlib-only so it runs in environments without jax.
"""
from __future__ import annotations

import ast
import io
import os
import re
import tokenize
from typing import Iterable, List, Optional, Sequence, Tuple

from .findings import Finding
from .rules import RULES, Rule

__all__ = ["lint_source", "lint_file", "lint_paths", "iter_py_files"]

_WAIVER_RE = re.compile(
    r"#\s*reprolint:\s*disable=([A-Z]{2}\d{3}(?:\s*,\s*[A-Z]{2}\d{3})*)"
    r"\s*(.*)")

_SKIP_DIRS = frozenset((".git", "__pycache__", ".pytest_cache",
                        "node_modules", ".eggs", "build", "dist"))


def _waivers(src: str) -> dict:
    """line -> (set of rule ids, reason, comment line no).

    Scans real COMMENT tokens (not strings/docstrings), so documenting
    the waiver syntax in prose does not register a waiver.
    """
    out = {}
    try:
        tokens = tokenize.generate_tokens(io.StringIO(src).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _WAIVER_RE.search(tok.string)
            if m:
                i = tok.start[0]
                ids = {s.strip() for s in m.group(1).split(",")}
                out[i] = (ids, m.group(2).strip(), i)
    except tokenize.TokenError:
        pass  # unparseable file -> handled by the ast.parse error path
    return out


def lint_source(src: str, relpath: str,
                rules: Sequence[Rule] = RULES,
                severity: str = "error") -> List[Finding]:
    """Lint one source string. Returns findings with waivers applied and
    RL000 findings for unexplained suppressions."""
    findings: List[Finding] = []
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Finding(rule_id="RL000", path=relpath,
                        line=e.lineno or 1,
                        message=f"file does not parse: {e.msg}",
                        severity="error")]

    waivers = _waivers(src)
    used: set = set()

    for rule in rules:
        if not rule.applies(relpath):
            continue
        for f in rule.check(tree, src, relpath):
            waiver = waivers.get(f.line) or waivers.get(f.line - 1)
            if waiver and f.rule_id in waiver[0]:
                ids, reason, wline = waiver
                used.add(wline)
                if reason:
                    f = f._replace(waived=True, waive_reason=reason)
                else:
                    findings.append(Finding(
                        rule_id="RL000", path=relpath, line=wline,
                        message=(f"waiver for {f.rule_id} has no reason — "
                                 f"`# reprolint: disable={f.rule_id} "
                                 f"<why>` is required"),
                        severity="error"))
            if f.severity != severity and not f.waived:
                f = f._replace(severity=severity)
            findings.append(f)

    # Waivers that never matched a finding are stale — surface them so
    # suppressions cannot silently outlive the code they excused.
    for wline, (ids, reason, _) in waivers.items():
        if wline not in used:
            findings.append(Finding(
                rule_id="RL000", path=relpath, line=wline,
                message=(f"stale waiver for {', '.join(sorted(ids))}: no "
                         f"matching finding on this or the next line"),
                severity=severity))

    findings.sort(key=lambda f: (f.line, f.rule_id))
    return findings


def lint_file(path: str, root: str,
              rules: Sequence[Rule] = RULES,
              severity: str = "error") -> List[Finding]:
    relpath = os.path.relpath(path, root).replace(os.sep, "/")
    with open(path, "r", encoding="utf-8") as fh:
        src = fh.read()
    return lint_source(src, relpath, rules=rules, severity=severity)


def iter_py_files(paths: Iterable[str], root: str) -> List[str]:
    out: List[str] = []
    for p in paths:
        ap = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(ap) and ap.endswith(".py"):
            out.append(ap)
            continue
        for dirpath, dirnames, filenames in os.walk(ap):
            dirnames[:] = sorted(d for d in dirnames if d not in _SKIP_DIRS)
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(os.path.join(dirpath, fn))
    return out


def lint_paths(paths: Iterable[str], root: str,
               rules: Sequence[Rule] = RULES,
               severity: str = "error") -> List[Finding]:
    findings: List[Finding] = []
    for f in iter_py_files(paths, root):
        findings.extend(lint_file(f, root, rules=rules, severity=severity))
    return findings
