"""Construction-time hashability backstop for jit-static specs.

The RL004 AST rule catches the declared shape of a config class; this
helper catches the values. ``check_hashable_fields`` is called from
``Estimator.__new__``, ``RobustDecodeConfig.__post_init__`` and
``ArchConfig.__post_init__`` so a spec carrying a list/dict/array field
fails at construction — naming the offending field — instead of
surfacing later as a TypeError at the jit boundary (or worse, as a
silent retrace per call).

Stdlib-only; must import without jax.
"""
from __future__ import annotations

from typing import Any, Iterable, Tuple

__all__ = ["check_hashable_fields", "UnhashableFieldError"]


class UnhashableFieldError(TypeError):
    """A jit-static spec was constructed with an unhashable field."""


def _field_items(obj: Any) -> Iterable[Tuple[str, Any]]:
    if hasattr(obj, "_asdict"):          # NamedTuple
        return obj._asdict().items()
    if hasattr(obj, "__dataclass_fields__"):
        return ((name, getattr(obj, name))
                for name in obj.__dataclass_fields__)
    return vars(obj).items()


def check_hashable_fields(obj: Any) -> None:
    """Raise :class:`UnhashableFieldError` naming the first unhashable
    field of a spec object (NamedTuple or dataclass instance)."""
    cls = type(obj).__name__
    for name, value in _field_items(obj):
        try:
            hash(value)
        except TypeError:
            raise UnhashableFieldError(
                f"{cls}.{name} = {value!r} ({type(value).__name__}) is "
                f"unhashable; {cls} is used as a jit static argument and "
                f"every field must be hashable (use a tuple / frozen "
                f"type) [reprolint RL004]") from None
