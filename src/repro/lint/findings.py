"""Finding records and the machine-readable reprolint report.

One JSON document carries both layers (DESIGN.md §10): layer-1 AST
findings (``rule_id``/``path``/``line``/``message``/``severity``, plus
waiver state) and layer-2 audit results (one entry per verified entry
point). CI consumes the JSON; humans get the text rendering.
"""
from __future__ import annotations

import json
from typing import List, NamedTuple, Optional

__all__ = ["Finding", "AuditResult", "Report"]


class Finding(NamedTuple):
    """One layer-1 lint finding, anchored to a source line."""

    rule_id: str
    path: str          # repo-relative, forward slashes
    line: int
    message: str
    severity: str = "error"   # "error" | "warning"
    waived: bool = False
    waive_reason: str = ""

    def to_json(self) -> dict:
        return dict(self._asdict())

    def render(self) -> str:
        tag = f"[{self.rule_id}]"
        suffix = f"  (waived: {self.waive_reason})" if self.waived else ""
        return f"{self.path}:{self.line}: {tag} {self.message}{suffix}"


class AuditResult(NamedTuple):
    """One layer-2 trace-auditor verdict for a public entry point."""

    check_id: str
    entry_point: str
    status: str        # "ok" | "fail" | "skip"
    detail: str = ""

    def to_json(self) -> dict:
        return dict(self._asdict())

    def render(self) -> str:
        return (f"{self.status.upper():5s} [{self.check_id}] "
                f"{self.entry_point}: {self.detail}")


class Report(NamedTuple):
    findings: List[Finding]
    audit: List[AuditResult]

    # -- aggregation --------------------------------------------------------

    @property
    def active(self) -> List[Finding]:
        """Findings that count against the exit code (not waived)."""
        return [f for f in self.findings if not f.waived]

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.active if f.severity == "error"]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.active if f.severity == "warning"]

    @property
    def audit_failures(self) -> List[AuditResult]:
        return [a for a in self.audit if a.status == "fail"]

    def summary(self) -> dict:
        return {
            "errors": len(self.errors),
            "warnings": len(self.warnings),
            "waived": sum(1 for f in self.findings if f.waived),
            "audit_ok": sum(1 for a in self.audit if a.status == "ok"),
            "audit_fail": len(self.audit_failures),
            "audit_skip": sum(1 for a in self.audit if a.status == "skip"),
        }

    # -- rendering ----------------------------------------------------------

    def to_json(self, paths: Optional[List[str]] = None) -> str:
        return json.dumps(
            {
                "version": 1,
                "paths": paths or [],
                "findings": [f.to_json() for f in self.findings],
                "audit": [a.to_json() for a in self.audit],
                "summary": self.summary(),
            },
            indent=2,
        )

    def render_text(self) -> str:
        lines = []
        for f in self.findings:
            lines.append(f.render())
        if self.audit:
            if lines:
                lines.append("")
            lines.append("trace audit:")
            for a in self.audit:
                lines.append("  " + a.render())
        s = self.summary()
        if lines:
            lines.append("")
        lines.append(
            f"reprolint: {s['errors']} error(s), {s['warnings']} "
            f"warning(s), {s['waived']} waived"
            + (f"; audit {s['audit_ok']} ok / {s['audit_fail']} fail / "
               f"{s['audit_skip']} skip" if self.audit else ""))
        return "\n".join(lines)
