"""Layer-2 abstract trace auditor (RL201–RL211, DESIGN.md §10).

Drives the public entry points through ``jax.eval_shape`` /
``jax.make_jaxpr`` — no array is ever materialized, no kernel executed —
and verifies the invariants the AST layer cannot see: wire shapes and
dtypes, the §9 upper-triangle wire length, the coordinatewise gate, the
worker-divisibility guards, and recompile stability of the static specs.

Entry points audited (ISSUE acceptance: ≥ 6):

1. ``dist.robust_reduce.aggregate_stacked_rrs``       (RL201, RL204)
2. ``dist.robust_reduce.aggregate_symmetric_stacked`` (RL202)
3. ``dist.robust_reduce.robust_dot``/``robust_backward`` (RL205)
4. ``train.step.make_train_step``                     (RL206, RL205)
5. ``serve.engine.ServeEngine`` prefill + decode loop (RL207, RL204)
6. ``infer.sandwich.infer`` (sandwich CI path)        (RL208)
7. ``dist.consensus.aggregate_stacked_consensus``     (RL210)
8. ``core.adaptive`` init_state/apply_adaptive carry  (RL211)
9. every static spec: Estimator / ConsensusConfig /
   FaultPlan / ArchConfig / RobustDecodeConfig /
   Sampling                                           (RL209)

The recompile guard (RL209) is the one check that *runs* a jitted
function — a scalar-add wrapper with the spec as its static argument,
called twice with equal-valued-but-freshly-constructed specs, counting
Python traces. That is the only way to observe the jit cache key; the
wrapper's cost is one scalar add.

Mesh-dependent checks report ``status="skip"`` when fewer than 2
devices are visible (the CLI's ``--host-devices N`` sets
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax
imports).
"""
from __future__ import annotations

import traceback
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from .findings import AuditResult

__all__ = ["run_audit", "recompile_stability", "divisibility_audit",
           "consensus_validity_audit"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _result(check_id: str, entry: str, fn: Callable[[], str]) -> AuditResult:
    """Run one check body; it returns the ok-detail or raises."""
    try:
        return AuditResult(check_id, entry, "ok", fn())
    except _Skip as s:
        return AuditResult(check_id, entry, "skip", str(s))
    except Exception as e:  # noqa: BLE001 — every failure is a finding
        detail = f"{type(e).__name__}: {e}"
        if not str(e):
            detail = traceback.format_exc(limit=3)
        return AuditResult(check_id, entry, "fail", detail)


class _Skip(Exception):
    pass


def _mesh1d():
    nd = jax.device_count()
    if nd < 2:
        raise _Skip(f"needs >= 2 devices for a worker mesh, have {nd} "
                    f"(run the CLI with --host-devices 8)")
    return jax.make_mesh((nd,), ("data",)), nd


def _expect_raises(thunk, exc, must_contain: str, what: str) -> None:
    try:
        thunk()
    except exc as e:
        if must_contain not in str(e):
            raise AssertionError(
                f"{what}: raised {type(e).__name__} but the message "
                f"{str(e)!r} does not mention {must_contain!r}")
        return
    raise AssertionError(f"{what}: expected {exc.__name__}, nothing raised")


# ---------------------------------------------------------------------------
# RL201 — RRS wire shapes/dtypes
# ---------------------------------------------------------------------------

def _check_rrs_wire() -> List[AuditResult]:
    def body():
        from ..core.estimator import Estimator
        from ..dist.robust_reduce import aggregate_stacked_rrs

        mesh, nw = _mesh1d()
        est = Estimator(method="vrmom", K=3)
        # deliberately wire-unfriendly sizes: total coords 4*6+5 = 29,
        # coprime with any nw >= 2, so the zero-pad path is exercised.
        grads = {"w": _sds((nw, 4, 6), jnp.bfloat16),
                 "b": _sds((nw, 5), jnp.float32)}
        out = jax.eval_shape(
            lambda g: aggregate_stacked_rrs(g, mesh, ("data",), est), grads)
        assert out["w"].shape == (4, 6), out["w"].shape
        assert out["b"].shape == (5,), out["b"].shape
        assert out["w"].dtype == jnp.bfloat16, (
            f"bf16 leaf upcast to {out['w'].dtype} on the wire")
        assert out["b"].dtype == jnp.float32, out["b"].dtype
        return (f"[{nw}, ...] pytree -> worker dim removed, dtypes "
                f"preserved (bf16 stays bf16) across the padded f32 wire")

    return [_result("RL201", "dist.aggregate_stacked_rrs", body)]


# ---------------------------------------------------------------------------
# RL202 — §9 upper-triangle wire length
# ---------------------------------------------------------------------------

def _check_symmetric_wire() -> List[AuditResult]:
    def body():
        from ..core.estimator import Estimator
        from ..dist.robust_reduce import aggregate_symmetric_stacked

        W, p = 5, 7
        tri = p * (p + 1) // 2
        est = Estimator(method="vrmom", K=3)
        closed = jax.make_jaxpr(
            lambda m: aggregate_symmetric_stacked(m, est))(
                _sds((W, p, p), jnp.bfloat16))
        out_aval = closed.out_avals[0]
        assert out_aval.shape == (p, p), out_aval.shape
        assert out_aval.dtype == jnp.bfloat16, (
            f"symmetric aggregate upcast to {out_aval.dtype}")
        # the wire aval [W, p(p+1)/2] must appear in the jaxpr — and the
        # full [W, p*p] square must NOT be what rides the estimator.
        shapes = set()
        for eqn in closed.jaxpr.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(v, "aval", None)
                if aval is not None and getattr(aval, "shape", None):
                    shapes.add(tuple(aval.shape))
        assert (W, tri) in shapes, (
            f"no [W={W}, p(p+1)/2={tri}] wire aval in the jaxpr; "
            f"saw {sorted(shapes)}")
        return (f"[{W}, {p}, {p}] stack rides a [{W}, {tri}] "
                f"upper-triangle wire; output [{p}, {p}] {out_aval.dtype}")

    return [_result("RL202", "dist.aggregate_symmetric_stacked", body)]


# ---------------------------------------------------------------------------
# RL203 — coordinatewise gate
# ---------------------------------------------------------------------------

def _check_coordinatewise_gate() -> List[AuditResult]:
    def body():
        from ..core.estimator import Estimator
        from ..dist.robust_reduce import aggregate_stacked_auto
        from ..serve.robust import RobustDecodeConfig

        g = {"w": _sds((8, 12), jnp.float32)}
        for method in ("geometric_median", "krum"):
            _expect_raises(
                lambda m=method: jax.eval_shape(
                    lambda x: aggregate_stacked_auto(x, m), g),
                ValueError, "whole-vector",
                f"aggregate_stacked_auto({method!r})")
            _expect_raises(
                lambda m=method: RobustDecodeConfig(m=8, estimator=m),
                ValueError, "whole-vector",
                f"RobustDecodeConfig(estimator={method!r})")
        _expect_raises(
            lambda: Estimator(method="trimmed_mean", beta=0.05).validate(8),
            ValueError, "degrade",
            "trimmed_mean beta=0.05 at m=8 (trims 0 rows)")
        return ("GM/Krum rejected on the RRS wire and the replicated "
                "decode path; degenerate trimmed_mean rejected at "
                "validate()")

    return [_result("RL203", "Estimator.require_coordinatewise", body)]


# ---------------------------------------------------------------------------
# RL204 — wire dtype discipline
# ---------------------------------------------------------------------------

def _check_wire_dtype() -> List[AuditResult]:
    def body():
        from ..dist.robust_reduce import aggregate_stacked_auto
        from ..serve.robust import RobustDecodeConfig, robust_logits

        out = jax.eval_shape(
            lambda g: aggregate_stacked_auto(g, "vrmom"),
            {"w": _sds((8, 33), jnp.bfloat16)})
        assert out["w"].dtype == jnp.bfloat16, (
            f"bf16 gradient stack silently upcast to {out['w'].dtype}")
        rcfg = RobustDecodeConfig(m=4, estimator="median")
        logits = jax.eval_shape(
            lambda lr: robust_logits(lr, rcfg, jax.random.PRNGKey(0)),
            _sds((4, 2, 64), jnp.bfloat16))
        assert logits.shape == (2, 64), logits.shape
        assert logits.dtype == jnp.float32, (
            f"robust decode logits must be f32, got {logits.dtype}")
        return ("stacked aggregation returns the input dtype (bf16 in, "
                "bf16 out); robust decode logits are exactly f32")

    return [_result("RL204", "dist/serve wire dtypes", body)]


# ---------------------------------------------------------------------------
# RL205 — worker-divisibility guards
# ---------------------------------------------------------------------------

def _check_divisibility_guard() -> List[AuditResult]:
    def body():
        from ..dist.robust_reduce import robust_backward, robust_dot

        mesh, nw = _mesh1d()

        def loss(x, w):
            return jnp.sum(robust_dot(x, w))

        def grad_with_batch(B):
            with robust_backward(mesh, ("data",), "median"):
                return jax.eval_shape(
                    jax.grad(loss, argnums=1),
                    _sds((B, 2, 4), jnp.float32), _sds((4, 3), jnp.float32))

        _expect_raises(lambda: grad_with_batch(nw + 1),
                       ValueError, "not divisible",
                       f"robust_dot with B={nw + 1}, nw={nw}")
        dw = grad_with_batch(2 * nw)
        assert dw.shape == (4, 3), dw.shape
        return (f"B={nw + 1} refused at trace time; B={2 * nw} traces "
                f"with dW [4, 3] robustly aggregated over {nw} workers")

    return [_result("RL205", "dist.robust_dot / robust_backward", body)]


# ---------------------------------------------------------------------------
# RL206 — train step traces abstractly
# ---------------------------------------------------------------------------

def _audit_cfg():
    from ..configs import get
    return get("qwen3-1.7b").reduced()


def _check_train_step() -> List[AuditResult]:
    def body():
        from .. import optim as O
        from ..models import model as M
        from ..train.step import make_train_step

        mesh, nw = _mesh1d()
        cfg = _audit_cfg()
        setup = make_train_step(cfg, mesh, estimator="vrmom",
                                mode="stacked-rrs")
        assert setup.n_workers == nw, (setup.n_workers, nw)
        params = M.abstract_init(cfg)
        opt_state = jax.eval_shape(O.get(cfg.optimizer, lr=1e-3).init,
                                   params)
        batch = {"tokens": _sds((2 * nw, 32), jnp.int32)}
        p2, _, loss = jax.eval_shape(setup.step_fn, params, opt_state,
                                     batch, jax.random.PRNGKey(0))
        in_leaves = jax.tree.leaves(params)
        out_leaves = jax.tree.leaves(p2)
        assert len(in_leaves) == len(out_leaves)
        for a, b in zip(in_leaves, out_leaves):
            assert a.shape == b.shape and a.dtype == b.dtype, (a, b)
        assert loss.shape == (), loss.shape
        # inloop guard: indivisible global batch refused at trace time
        inloop = make_train_step(cfg, mesh, estimator="median",
                                 mode="inloop")
        _expect_raises(
            lambda: jax.eval_shape(
                inloop.step_fn, params, opt_state,
                {"tokens": _sds((nw + 1, 32), jnp.int32)},
                jax.random.PRNGKey(0)),
            ValueError, "divisible",
            f"inloop train step with batch {nw + 1} on {nw} workers")
        return (f"stacked-rrs step traces end-to-end on {nw} workers "
                f"(param/opt shapes stable, scalar loss); inloop refuses "
                f"an indivisible batch at trace time")

    return [_result("RL206", "train.make_train_step", body)]


# ---------------------------------------------------------------------------
# RL207 — serve prefill/decode + cache round-trip
# ---------------------------------------------------------------------------

def _check_serve_engine() -> List[AuditResult]:
    def body():
        from ..models import model as M
        from ..serve.engine import GREEDY, ServeEngine
        from ..serve.robust import RobustDecodeConfig

        cfg = _audit_cfg()
        params = M.abstract_init(cfg)
        engine = ServeEngine(cfg, params, max_len=48, n_slots=2,
                             robust=RobustDecodeConfig(m=2,
                                                       estimator="median"))
        logits, _ = jax.eval_shape(engine._prefill_fn(), params,
                                   {"tokens": _sds((2, 8), jnp.int32)})
        assert logits.shape == (2, cfg.vocab), logits.shape

        pool = jax.eval_shape(engine.make_pool)
        loop = engine._decode_loop_fn(3, GREEDY, pool=True)
        toks, caches_out = jax.eval_shape(
            loop, params, pool.caches, _sds((2,), jnp.int32),
            jax.random.PRNGKey(0))
        assert toks.shape == (3, 2), toks.shape
        assert toks.dtype == jnp.int32, toks.dtype
        in_l, in_def = jax.tree.flatten(pool.caches)
        out_l, out_def = jax.tree.flatten(caches_out)
        assert in_def == out_def, "cache tree structure changed in-loop"
        for a, b in zip(in_l, out_l):
            assert a.shape == b.shape and a.dtype == b.dtype, (
                f"cache leaf {a.shape}/{a.dtype} -> {b.shape}/{b.dtype}: "
                f"the stacked<->flat replica round-trip is not lossless")
        return ("prefill logits [B, V]; 3-step robust pool decode traces "
                "with a bit-identical cache tree (replica "
                "stacked<->flat round-trip lossless)")

    return [_result("RL207", "serve.ServeEngine prefill/decode", body)]


# ---------------------------------------------------------------------------
# RL208 — sandwich CI path
# ---------------------------------------------------------------------------

def _check_sandwich() -> List[AuditResult]:
    def body():
        from ..core.rcsl import LinearRegressionProblem, Shards
        from ..infer.sandwich import infer

        m, n, p = 4, 16, 3
        shards = Shards(X=_sds((m + 1, n, p), jnp.float32),
                        Y=_sds((m + 1, n), jnp.float32))
        res = jax.eval_shape(
            lambda s, t: infer(LinearRegressionProblem(), s, t,
                               estimator="vrmom", K=3),
            shards, _sds((p,), jnp.float32))
        assert res.ci.lower.shape == (p,), res.ci.lower.shape
        assert res.ci.upper.shape == (p,), res.ci.upper.shape
        assert res.cov.shape == (p, p), res.cov.shape
        assert res.H.shape == (p, p), res.H.shape
        assert res.Sigma.shape == (p, p), res.Sigma.shape
        return (f"machine stats -> robust moments -> Theorem-4 sandwich "
                f"traces abstractly: [p]={p} intervals, [p, p] covariance")

    return [_result("RL208", "infer.sandwich.infer", body)]


# ---------------------------------------------------------------------------
# RL210 — consensus wire shapes + n > 5f refusal
# ---------------------------------------------------------------------------

def _check_consensus() -> List[AuditResult]:
    def body():
        from ..core.estimator import Estimator
        from ..dist.consensus import (ConsensusAux, ConsensusConfig,
                                      aggregate_stacked_consensus)
        from ..dist.faults import FaultPlan

        mesh, nw = _mesh1d()
        est = Estimator(method="vrmom", K=3)
        f_ok = max((nw - 1) // 5, 0)
        grads = {"w": _sds((nw, 4, 6), jnp.bfloat16),
                 "b": _sds((nw, 5), jnp.float32)}
        for plan in (None, FaultPlan(dropout=0.25, n_crashed=1,
                                     crash_round=1)):
            out, aux = jax.eval_shape(
                lambda g: aggregate_stacked_consensus(
                    g, mesh, ("data",), est,
                    config=ConsensusConfig(f=f_ok, max_rounds=4),
                    plan=plan, key=jax.random.PRNGKey(0)),
                grads)
            assert out["w"].shape == (4, 6), out["w"].shape
            assert out["b"].shape == (5,), out["b"].shape
            assert out["w"].dtype == jnp.bfloat16, (
                f"bf16 leaf upcast to {out['w'].dtype} through the "
                f"round loop")
            assert out["b"].dtype == jnp.float32, out["b"].dtype
            assert isinstance(aux, ConsensusAux), type(aux)
            for name, leaf in zip(aux._fields, aux):
                assert leaf.shape == (), (
                    f"aux field {name} is not a scalar: {leaf.shape}")
        _expect_raises(
            lambda: jax.eval_shape(
                lambda g: aggregate_stacked_consensus(
                    g, mesh, ("data",), est,
                    config=ConsensusConfig(f=nw)),
                grads),
            ValueError, "n > 5f",
            f"consensus with f={nw} on {nw} peers")
        return (f"[{nw}, ...] pytree -> worker dim removed, dtypes "
                f"preserved through the static round loop (fault-free "
                f"and faulty plans); f={nw} refused at trace time")

    return [_result("RL210", "dist.aggregate_stacked_consensus", body)]


# ---------------------------------------------------------------------------
# RL211 — adaptive aggregation state is an explicit jit-pure carry
# ---------------------------------------------------------------------------

_IMMUTABLE = (type(None), bool, int, float, complex, str, bytes,
              tuple, frozenset)


def _check_adaptive_carry() -> List[AuditResult]:
    def body():
        from ..core import adaptive as AD
        from ..core.estimator import Estimator

        # 1. no mutable module-level state: every non-callable global
        # of repro.core.adaptive must be an immutable constant — a
        # module-level list/dict/array would leak state across steps
        # and silently break the jit-pure carry contract.
        mutable = []
        for gname, val in vars(AD).items():
            if gname.startswith("_") or callable(val):
                continue
            if type(val).__name__ == "module":
                continue
            if type(val).__module__ == "__future__":
                continue  # the `annotations` feature flag
            if not isinstance(val, _IMMUTABLE):
                mutable.append(f"{gname}: {type(val).__name__}")
        assert not mutable, (
            f"mutable module-level state in repro.core.adaptive: "
            f"{mutable}")

        # 2. init/apply round-trip under eval_shape: the carry's pytree
        # structure, shapes, and dtypes must be a fixed point, so the
        # train-step scan can thread it without retracing.
        nw, dim = 9, 40
        for method in ("auto_gm", "vrmom_adaptive"):
            est = Estimator(method=method, K=4)
            state = est.init_adaptive_state(nw, dim)
            out, new_state = jax.eval_shape(
                lambda x, s, e=est: e.apply_adaptive(x, s),
                _sds((nw, dim), jnp.float32),
                jax.tree.map(lambda l: _sds(l.shape, l.dtype), state))
            assert out.shape == (dim,), (method, out.shape)
            assert out.dtype == jnp.float32, (method, out.dtype)
            old_s = [(l.shape, jnp.dtype(l.dtype))
                     for l in jax.tree.leaves(state)]
            new_s = [(l.shape, jnp.dtype(l.dtype))
                     for l in jax.tree.leaves(new_state)]
            assert old_s == new_s, (
                f"{method}: carry is not a fixed point — "
                f"{old_s} -> {new_s}")

        # 3. non-adaptive estimators must refuse to mint a carry.
        _expect_raises(
            lambda: Estimator(method="vrmom", K=4)
            .init_adaptive_state(nw, dim),
            ValueError, "adaptive",
            "init_adaptive_state on a fixed-K estimator")
        return ("auto_gm/vrmom_adaptive carry round-trips with fixed "
                "shapes+dtypes; module globals immutable; fixed-K "
                "estimators refuse a carry")

    return [_result("RL211", "core.adaptive carry", body)]


# ---------------------------------------------------------------------------
# RL209 — recompile stability (public helper + the spec sweep)
# ---------------------------------------------------------------------------

def recompile_stability(name: str, factory: Callable[[], object],
                        ) -> AuditResult:
    """Verify a static-spec factory is jit-cache stable.

    ``factory()`` must build a *fresh* spec each call. The spec is used
    as ``static_argnums=0`` of a scalar-add jit; calling with two fresh
    equal specs must trace exactly once. Also checks ``hash(a) ==
    hash(b)`` and ``a == b`` directly, so a failure names the drift.
    """
    def body():
        a, b = factory(), factory()
        assert a is not b, (
            f"{name}: factory returned the same object twice — the "
            f"check needs freshly constructed specs")
        assert a == b, f"{name}: two fresh equal-valued specs are != "
        assert hash(a) == hash(b), (
            f"{name}: equal specs hash differently "
            f"({hash(a)} vs {hash(b)}) — every jit call retraces")
        traces = [0]

        def f(spec, x):
            traces[0] += 1
            return x + 1.0

        jf = jax.jit(f, static_argnums=0)
        x = jnp.zeros(())
        jf(a, x)
        jf(b, x)
        assert traces[0] == 1, (
            f"{name}: second call with a fresh equal spec retraced "
            f"(traces={traces[0]}) — jit cache key is unstable")
        return "two fresh equal specs -> one trace (cache key stable)"

    return _result("RL209", name, body)


def _check_recompile() -> List[AuditResult]:
    from ..configs.base import ArchConfig
    from ..core.estimator import Estimator
    from ..serve.engine import Sampling
    from ..serve.robust import RobustDecodeConfig

    from ..dist.consensus import ConsensusConfig
    from ..dist.faults import FaultPlan

    specs = [
        ("core.Estimator",
         lambda: Estimator(method="vrmom", K=4, backend="pallas")),
        ("core.Estimator[adaptive]",
         lambda: Estimator(method="auto_gm")),
        ("dist.ConsensusConfig",
         lambda: ConsensusConfig(f=1, eps=1e-3, trim="midpoint")),
        ("dist.FaultPlan",
         lambda: FaultPlan(dropout=0.1, n_crashed=1, crash_round=2)),
        ("configs.ArchConfig",
         lambda: ArchConfig(name="audit", family="dense", n_layers=1,
                            d_model=32, n_heads=2, n_kv_heads=1,
                            d_ff=64, vocab=64)),
        ("serve.RobustDecodeConfig",
         lambda: RobustDecodeConfig(m=4, estimator="median")),
        ("serve.Sampling",
         lambda: Sampling(method="top_k", temperature=0.7, top_k=5)),
    ]
    return [recompile_stability(name, fac) for name, fac in specs]


# ---------------------------------------------------------------------------
# public helper for config-level divisibility audits (used by tests)
# ---------------------------------------------------------------------------

def divisibility_audit(name: str, batch: int, n_workers: int) -> AuditResult:
    """Flag a config whose global batch the worker count cannot divide —
    the static precondition RL205 verifies the runtime guards enforce."""
    def body():
        if n_workers > 1 and batch % n_workers:
            raise AssertionError(
                f"global batch {batch} is not divisible by {n_workers} "
                f"workers: per-worker grouping breaks and the robust "
                f"guarantee does not apply")
        return f"batch {batch} / {n_workers} workers divides evenly"

    return _result("RL205", name, body)


def consensus_validity_audit(name: str, n: int, f: int) -> AuditResult:
    """Flag a consensus deployment outside the ``n > 5f`` validity
    region — the static precondition RL210 verifies the runtime
    refusal enforces. Mesh-free (pure arithmetic on the config), so
    configs can be audited before any device exists."""
    def body():
        from ..dist.consensus import ConsensusConfig

        if n <= 5 * f:
            raise AssertionError(
                f"n={n} peers with f={f} Byzantine faults violates "
                f"n > 5f: approximate consensus loses both validity "
                f"and convergence (need n >= {5 * f + 1})")
        ConsensusConfig(f=f).validate(n)
        return f"n={n}, f={f} satisfies n > 5f (margin {n - 5 * f})"

    return _result("RL210", name, body)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run_audit() -> List[AuditResult]:
    """Run every RL2xx check; never raises — failures are results."""
    results: List[AuditResult] = []
    results += _check_rrs_wire()
    results += _check_symmetric_wire()
    results += _check_coordinatewise_gate()
    results += _check_wire_dtype()
    results += _check_divisibility_guard()
    results += _check_train_step()
    results += _check_serve_engine()
    results += _check_sandwich()
    results += _check_consensus()
    results += _check_adaptive_carry()
    results += _check_recompile()
    return results
