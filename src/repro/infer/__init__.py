"""repro.infer: Byzantine-robust statistical inference for RCSL.

The paper's asymptotic-normality result made computable (DESIGN.md §9):
plug-in sandwich covariances built from robustly-aggregated per-machine
statistics (``sandwich``), and a fully-compiled Monte-Carlo coverage
harness that reproduces the Section 4 coverage/width experiments
(``coverage``).

    from repro.infer import infer, coverage_run
    res = infer(problem, shards, theta_hat, estimator="vrmom", level=0.95)
    res.ci.lower, res.ci.upper          # per-coordinate CIs
    cell = coverage_run(model="linear", attack="gaussian", alpha=0.1)
    cell.summary()["coverage"]          # ~ 0.95
"""
from .coverage import CoverageCell, coverage_run
from .sandwich import (CIResult, InferenceResult, MachineStats, bvn_cdf,
                       confidence_intervals, contamination_inflation,
                       corrupt_stats, cov_factor, infer, machine_stats,
                       mom_cov_factor, robust_moments, sandwich_cov,
                       vrmom_cov_factor)

__all__ = [
    "bvn_cdf",
    "vrmom_cov_factor",
    "mom_cov_factor",
    "cov_factor",
    "MachineStats",
    "machine_stats",
    "corrupt_stats",
    "robust_moments",
    "sandwich_cov",
    "confidence_intervals",
    "CIResult",
    "InferenceResult",
    "infer",
    "CoverageCell",
    "coverage_run",
]
