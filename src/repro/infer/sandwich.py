"""Plug-in sandwich covariance and confidence intervals for RCSL.

The paper's headline theoretical result — the first asymptotic-normality
theorem for Byzantine-robust distributed learning — says that at the
RCSL fixed point the estimator solves the *robustly aggregated*
estimating equation ``gbar(theta_hat) = 0``, hence

    sqrt(N) (theta_hat - theta*)  ->  N(0,  H^{-1} C(Sigma_g) H^{-1})

where ``H = E[grad^2 f]`` is the population Hessian, ``Sigma_g =
Cov(grad f)`` the per-sample gradient covariance, and ``C`` the
aggregator's asymptotic covariance transform: Theorem 4 (eq. 13/14) for
VRMOM, Proposition 1 (eq. 17) for MOM, the identity for the mean. This
module turns that statement into confidence intervals a master can
actually compute in the Byzantine setting (DESIGN.md §9):

1. *Per-machine statistics* (:func:`machine_stats`): every machine
   reports its local Hessian and the first/second moments of its
   per-sample gradients via the ``Problem`` interface
   (``local_hessian`` / ``local_moments``, ``core/rcsl.py``). Byzantine
   machines report garbage — :func:`corrupt_stats` models that with the
   same ``core.attacks`` used on gradients.
2. *Robust plug-in* (:func:`robust_moments`): the stacked ``[m+1, ...]``
   statistics are aggregated coordinate-wise with an §7 ``Estimator``
   (symmetric-matrix stacks ride
   ``dist.robust_reduce.aggregate_symmetric_stacked``, which aggregates
   only the upper triangle and mirrors — half the wire, exactly
   symmetric output), so the covariance estimate survives the same
   ``floor(alpha*m)`` corrupted machines as the point estimate.
3. *Sandwich + factor* (:func:`sandwich_cov`): ``Xi = H^{-1} C H^{-1}``
   with ``C`` from :func:`vrmom_cov_factor` — a fully jittable
   Theorem-4 evaluation built on :func:`bvn_cdf`, a fixed-node
   Gauss-Legendre bivariate-normal CDF (the host-side numpy
   ``core.vrmom.vrmom_asymptotic_cov`` is its test oracle).
4. *Intervals* (:func:`confidence_intervals`): per-coordinate normal
   CIs ``theta_hat_l ± z sqrt(Xi_ll / N)`` and Bonferroni simultaneous
   bands.

Everything composes with jit/vmap — the coverage harness
(:mod:`repro.infer.coverage`) runs hundreds of full replications as one
compiled program.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.special import ndtr, ndtri

from ..core import attacks as _attacks
from ..core.estimator import Estimator
from ..core.vrmom import deltas, psi_sum, sigma_k_sq

__all__ = [
    "bvn_cdf",
    "vrmom_cov_factor",
    "mom_cov_factor",
    "cov_factor",
    "trimmed_mean_variance_factor",
    "contamination_inflation",
    "MachineStats",
    "machine_stats",
    "corrupt_stats",
    "robust_moments",
    "sandwich_cov",
    "confidence_intervals",
    "CIResult",
    "InferenceResult",
    "infer",
]

# Fixed Gauss-Legendre rule on [0, 1]; 24 nodes give ~1e-7 absolute
# accuracy on the (smooth, bounded) bvn integrand — far below the
# Monte-Carlo noise any coverage experiment can resolve.
_GL_X, _GL_W = np.polynomial.legendre.leggauss(24)
_GL_X01 = jnp.asarray((_GL_X + 1.0) / 2.0, jnp.float32)
_GL_W01 = jnp.asarray(_GL_W / 2.0, jnp.float32)

_RHO_EDGE = 1.0 - 1e-6


def bvn_cdf(a, b, rho):
    """Standard bivariate normal CDF ``P(Z1 <= a, Z2 <= b)``, jittable.

    Uses the arcsin substitution of Drezner-Wesolowsky's single
    integral,

        P = Phi(a) Phi(b) + (1/2pi) int_0^{asin(rho)}
              exp(-(a^2 - 2 a b sin t + b^2) / (2 cos^2 t)) dt,

    whose integrand is smooth on the whole rho range, evaluated with a
    fixed Gauss-Legendre rule — no data-dependent shapes, so it
    broadcasts and vmaps freely. ``|rho| -> 1`` is handled exactly
    (``Phi(min(a,b))`` / ``max(0, Phi(a)+Phi(b)-1)``), which the
    correlation-matrix diagonal always hits.
    """
    a, b, rho = jnp.broadcast_arrays(
        jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32),
        jnp.asarray(rho, jnp.float32))
    r = jnp.clip(rho, -_RHO_EDGE, _RHO_EDGE)
    s = jnp.arcsin(r)[..., None]                       # [..., 1]
    theta = s * _GL_X01                                # [..., Q]
    sin_t = jnp.sin(theta)
    cos2_t = jnp.maximum(jnp.cos(theta) ** 2, 1e-12)
    a_e, b_e = a[..., None], b[..., None]
    integrand = jnp.exp(-(a_e * a_e - 2.0 * a_e * b_e * sin_t + b_e * b_e)
                        / (2.0 * cos2_t))
    quad = jnp.sum(_GL_W01 * integrand, axis=-1) * s[..., 0]
    base = ndtr(a) * ndtr(b) + quad / (2.0 * jnp.pi)
    hi = ndtr(jnp.minimum(a, b))                       # rho -> +1
    lo = jnp.maximum(ndtr(a) + ndtr(b) - 1.0, 0.0)     # rho -> -1
    return jnp.where(rho >= _RHO_EDGE, hi,
                     jnp.where(rho <= -_RHO_EDGE, lo, base))


def _corr_parts(Sigma, eps=1e-12):
    Sigma = jnp.asarray(Sigma, jnp.float32)
    var = jnp.clip(jnp.diagonal(Sigma), eps, None)
    sd = jnp.sqrt(var)
    corr = jnp.clip(Sigma / jnp.outer(sd, sd), -1.0, 1.0)
    return sd, corr


def vrmom_cov_factor(Sigma, K: int = 10):
    """Theorem 4 (eq. 13/14) asymptotic covariance ``C`` of VRMOM, jittable.

    ``sqrt(N)(vrmom - mu) -> N(0, C)`` for machine means with per-sample
    covariance ``Sigma``. Jit/vmap-compatible twin of the host-side
    ``core.vrmom.vrmom_asymptotic_cov`` (its numerical oracle in
    ``tests/test_infer.py``); ``K`` is static under jit.
    """
    sd, corr = _corr_parts(Sigma)
    d = deltas(K)                                       # [K]
    taus = jnp.arange(1, K + 1, dtype=jnp.float32) / (K + 1)
    P = bvn_cdf(d[None, None, :, None], d[None, None, None, :],
                corr[:, :, None, None])                 # [p, p, K, K]
    acc = jnp.sum(P - taus[:, None] * taus[None, :], axis=(-2, -1))
    return acc / (psi_sum(K) ** 2) * jnp.outer(sd, sd)


def mom_cov_factor(Sigma):
    """Proposition 1 (eq. 17) asymptotic covariance of MOM, closed form.

    ``2 pi P(0,0;rho) - pi/2`` collapses to ``arcsin(rho)`` — exact, no
    quadrature. The diagonal recovers Minsker's ``pi/2``.
    """
    sd, corr = _corr_parts(Sigma)
    return jnp.arcsin(corr) * jnp.outer(sd, sd)


def trimmed_mean_variance_factor(beta: float) -> float:
    """Asymptotic variance of the symmetric ``beta``-trimmed mean of
    N(0,1) samples (host-side float; ``beta`` is static):

        [ int_{z_b}^{z_{1-b}} z^2 phi(z) dz + 2 b z_b^2 ] / (1-2b)^2

    with ``z_b = Phi^{-1}(beta)`` — the winsorized influence function
    ``clip(z, z_b, z_{1-b}) / (1-2b)`` squared and integrated.
    """
    if not 0.0 <= beta < 0.5:
        raise ValueError(f"beta must be in [0, 0.5), got {beta}")
    if beta == 0.0:
        return 1.0
    from ..core.vrmom import _ndtri_np

    zb = float(np.abs(_ndtri_np(beta)))
    phi = math.exp(-0.5 * zb * zb) / math.sqrt(2.0 * math.pi)
    # int_{-z}^{z} t^2 phi(t) dt = (2 Phi(z) - 1) - 2 z phi(z)
    core = (1.0 - 2.0 * beta) - 2.0 * zb * phi
    return (core + 2.0 * beta * zb * zb) / (1.0 - 2.0 * beta) ** 2


def cov_factor(Sigma, est: Estimator):
    """The ``C(Sigma)`` transform matching an aggregation method.

    ``vrmom`` -> Theorem 4, ``median``/``mom`` -> Proposition 1,
    ``mean`` -> identity (the CLT), ``trimmed_mean`` -> winsorized-IF
    scaling (exact diagonal; the near-linear IF makes the off-diagonal
    scaling a close approximation). The adaptive tier (§14) uses its
    honest-regime asymptotics — at ``alpha_hat = 0`` the adaptive
    estimators ARE their fixed baselines: ``vrmom_adaptive`` ->
    Theorem 4 at the configured K; ``auto_gm`` -> Proposition 1
    (conservative: the spatial median is asymptotically at least as
    efficient as the coordinate-wise median it is bounded by). Other
    estimators have no normality theory in the paper and are rejected.
    """
    if est.method in ("vrmom", "vrmom_adaptive"):
        return vrmom_cov_factor(Sigma, K=est.K)
    if est.method in ("median", "mom", "auto_gm"):
        return mom_cov_factor(Sigma)
    if est.method == "trimmed_mean":
        return (trimmed_mean_variance_factor(est.beta)
                * jnp.asarray(Sigma, jnp.float32))
    if est.method == "mean":
        return jnp.asarray(Sigma, jnp.float32)
    raise ValueError(
        f"no asymptotic-normality result for estimator {est.method!r}; "
        "inference supports vrmom, median/mom, trimmed_mean, mean, and "
        "the adaptive tier (auto_gm, vrmom_adaptive)")


def contamination_inflation(alpha: float,
                            est: Union[str, Estimator] = "vrmom") -> float:
    """Finite-alpha variance inflation of the CIs (DESIGN.md §9).

    The paper's CLT treats the Byzantine fraction as asymptotically
    vanishing; at a *fixed* alpha the estimators acquire extra variance
    even under a *symmetric* attack. First-order influence-function
    analysis at the worst symmetric contamination (garbage at +-inf,
    each side with probability 1/2, in machine-mean units z):

    * the median's IF is ``sign(z) sqrt(pi/2)``, and its sparsity
      denominator shrinks to ``(1-a) f`` at the mixture, scaling the IF
      by ``(1-a)^{-1}``;
    * VRMOM's quantile-count correction has IF
      ``-(count(z) - K/2) / psi_sum`` with a *constant* (not estimated)
      denominator — no sparsity scaling — and a garbage value of
      ``+- K / (2 psi_sum)``, reinforcing the median's garbage IF.

    With ``a = pi/2`` (median IF variance), ``b = sigma_K^2``
    (correction IF variance — eq. (9) itself), ``c = -pi/4`` (their
    covariance, from ``a + 2c = 0``), the contaminated variance over
    the clean ``sigma_K^2`` is

        [(1-al) ((1-al)^{-2} a + b + 2 (1-al)^{-1} c)
         + al ((1-al)^{-1} sqrt(pi/2) + K/(2 psi_sum))^2] / sigma_K^2 .

    For the plain median the correction terms vanish and the formula
    collapses to the exact rank-offset result ``(1-al)^{-2}``; at
    ``al = 0`` both are 1. The scalar multiplies the whole sandwich —
    empirical coverage across attacks is validated in
    ``BENCH_inference.json``. One-sided coordinated attacks (e.g.
    ``wrong_value``) additionally *bias* the median by ``O(alpha * s)``
    — a non-vanishing term no variance correction can absorb; the
    coverage tables report that degradation honestly.
    """
    if not 0.0 <= alpha < 0.5:
        raise ValueError(f"alpha must be in [0, 0.5), got {alpha}")
    if alpha == 0.0:
        return 1.0
    est = Estimator.coerce(est)
    g = 1.0 / (1.0 - alpha)
    if est.method in ("median", "mom", "trimmed_mean", "auto_gm"):
        # Rank-offset result for the median; the winsorized trimmed
        # mean and the (median-bounded) auto_gm inherit the same
        # first-order sparsity scaling.
        return g * g
    if est.method == "mean":
        return 1.0  # no robustness, no meaningful symmetric-garbage limit
    a = math.pi / 2.0
    b = sigma_k_sq(est.K)
    c = -math.pi / 4.0
    honest = g * g * a + b + 2.0 * g * c
    garbage = (g * math.sqrt(a) + est.K / (2.0 * psi_sum(est.K))) ** 2
    return ((1.0 - alpha) * honest + alpha * garbage) / b


# ---------------------------------------------------------------------------
# Per-machine statistics and their robust aggregation
# ---------------------------------------------------------------------------


class MachineStats(NamedTuple):
    """Stacked per-machine inference statistics (worker axis 0).

    hessian: ``[m+1, p, p]`` local Hessians at theta_hat.
    grad1:   ``[m+1, p]``    local mean per-sample gradient.
    grad2:   ``[m+1, p, p]`` local second moment ``E_n[g g^T]``.
    n:       per-machine sample size (python int; static).
    """

    hessian: jnp.ndarray
    grad1: jnp.ndarray
    grad2: jnp.ndarray
    n: int


def machine_stats(problem, theta, shards) -> MachineStats:
    """Compute every machine's (Hessian, gradient-moment) report."""

    def one(X, Y):
        H = problem.local_hessian(theta, X, Y)
        g1, g2 = problem.local_moments(theta, X, Y)
        return H, g1, g2

    H, g1, g2 = jax.vmap(one)(shards.X, shards.Y)
    return MachineStats(H, g1, g2, int(shards.X.shape[1]))


def corrupt_stats(key, stats: MachineStats, mask, attack: str) -> MachineStats:
    """Byzantine machines report arbitrary statistics, not just arbitrary
    gradients: apply a ``core.attacks`` transform to each stacked leaf
    (rows selected by ``mask``; row 0, the master, is never corrupted by
    ``attacks.byzantine_mask``)."""
    fn = _attacks.get(attack)
    kh, k1, k2 = jax.random.split(key, 3)
    return MachineStats(
        hessian=fn(kh, stats.hessian, mask),
        grad1=fn(k1, stats.grad1, mask),
        grad2=fn(k2, stats.grad2, mask),
        n=stats.n,
    )


def robust_moments(stats: MachineStats, est: Union[str, Estimator] = "vrmom"):
    """Aggregate stacked statistics into plug-in ``(H_hat, Sigma_hat)``.

    Coordinate-wise robust aggregation over the machine axis — the
    symmetric stacks through
    ``dist.robust_reduce.aggregate_symmetric_stacked`` (upper-triangle
    wire, DESIGN.md §9) — then ``Sigma_hat = E[gg^T] - g1 g1^T``.
    Like ``core.rcsl.aggregate_gradients``, the statistical path runs
    the jnp backend: the stacks are tiny and whole-vector estimators
    are not needed here.
    """
    from ..dist.robust_reduce import aggregate_symmetric_stacked

    est = Estimator.coerce(est, backend="jnp").require_stackable(
        "plug-in covariance aggregation (repro.infer)")
    H = aggregate_symmetric_stacked(stats.hessian, est)
    g2 = aggregate_symmetric_stacked(stats.grad2, est)
    g1 = est.apply(stats.grad1.astype(jnp.float32), axis=0)
    Sigma = g2 - jnp.outer(g1, g1)
    return H, Sigma


def sandwich_cov(H, Sigma, est: Union[str, Estimator] = "vrmom"):
    """``Xi = H^{-1} C(Sigma) H^{-1}``: the asymptotic covariance of
    ``sqrt(N)(theta_hat - theta*)`` for an RCSL run aggregated with
    ``est``. ``H`` is symmetrized before the solves."""
    est = Estimator.coerce(est)
    C = cov_factor(Sigma, est)
    Hs = 0.5 * (H + H.T).astype(jnp.float32)
    HinvC = jnp.linalg.solve(Hs, C)
    return jnp.linalg.solve(Hs, HinvC.T).T


# ---------------------------------------------------------------------------
# Intervals
# ---------------------------------------------------------------------------


class CIResult(NamedTuple):
    """Per-coordinate confidence intervals at a nominal level.

    lower/upper: ``[p]`` bounds; se: ``[p]`` standard errors
    ``sqrt(Xi_ll / N)``; z: the critical value actually used (Bonferroni-
    adjusted when simultaneous).
    """

    lower: jnp.ndarray
    upper: jnp.ndarray
    se: jnp.ndarray
    level: float
    z: jnp.ndarray


def confidence_intervals(theta, Xi, N: int, level: float = 0.95,
                         simultaneous: bool = False) -> CIResult:
    """Normal plug-in CIs ``theta_l ± z sqrt(Xi_ll / N)``.

    ``simultaneous=True`` applies the Bonferroni correction
    ``z_{1 - (1-level)/(2p)}`` so the band covers all p coordinates
    jointly at the nominal level.
    """
    theta = jnp.asarray(theta)
    p = theta.shape[-1]
    q = (1.0 - level) / (p if simultaneous else 1.0)
    z = ndtri(1.0 - q / 2.0)
    se = jnp.sqrt(jnp.clip(jnp.diagonal(Xi), 0.0, None) / N)
    half = z * se
    return CIResult(lower=theta - half, upper=theta + half, se=se,
                    level=level, z=z)


class InferenceResult(NamedTuple):
    """Everything the plug-in inference layer produces for one RCSL run."""

    theta: jnp.ndarray    # [p] point estimate the CIs are centred on
    ci: CIResult          # per-coordinate (or simultaneous) intervals
    cov: jnp.ndarray      # [p, p] sandwich Xi (covariance of sqrt(N) error)
    H: jnp.ndarray        # [p, p] robust plug-in Hessian
    Sigma: jnp.ndarray    # [p, p] robust plug-in gradient covariance
    N: int                # total sample size (m+1) * n


def infer(problem, shards, theta,
          estimator: Union[str, Estimator] = "vrmom", K: int = 10,
          level: float = 0.95, simultaneous: bool = False,
          alpha: float = 0.0, attack: str = "none",
          key: Optional[jax.Array] = None,
          assumed_alpha: Optional[float] = None) -> InferenceResult:
    """Plug-in inference for an RCSL point estimate (DESIGN.md §9).

    ``estimator`` names the aggregation the point estimate was computed
    with — it is used both to aggregate the per-machine statistics and
    to pick the asymptotic factor ``C`` (Theorem 4 for VRMOM). ``alpha``
    is the assumed Byzantine fraction: it scales the sandwich by the
    finite-alpha :func:`contamination_inflation` (a no-op at 0), and —
    for simulations — with ``attack``/``key`` it corrupts the stacked
    statistics of ``floor(alpha*m)`` machines before aggregation, so the
    CI is computed under the same threat model the estimate survived.
    ``assumed_alpha`` splits the two roles for the regime matrix
    (DESIGN.md §14): corruption still happens at the *true* ``alpha``,
    but the inflation uses the analyst's assumption — ``0.0`` models a
    master unaware of the contamination (the fixed-estimator arms),
    while the adaptive arms de-bias through their own census. Default
    ``None`` keeps the legacy behavior (inflation at the true alpha).
    Fully jittable (estimator/K/level/shapes static).
    """
    est = Estimator.coerce(estimator, backend="jnp")
    if isinstance(estimator, str) and est.method in ("vrmom",
                                                     "vrmom_adaptive"):
        est = est._replace(K=K)
    stats = machine_stats(problem, theta, shards)
    if attack != "none" and alpha > 0.0:
        if key is None:
            raise ValueError("corrupting stats (attack != 'none') needs a key")
        mask = _attacks.byzantine_mask(stats.hessian.shape[0], alpha)
        stats = corrupt_stats(key, stats, mask, attack)
    H, Sigma = robust_moments(stats, est)
    infl_alpha = alpha if assumed_alpha is None else assumed_alpha
    Xi = sandwich_cov(H, Sigma, est) * contamination_inflation(infl_alpha, est)
    N = stats.hessian.shape[0] * stats.n
    ci = confidence_intervals(theta, Xi, N, level=level,
                              simultaneous=simultaneous)
    return InferenceResult(theta=theta, ci=ci, cov=Xi, H=H, Sigma=Sigma, N=N)
