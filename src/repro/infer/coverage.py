"""Vectorized Monte-Carlo coverage harness for the plug-in CIs.

Reproduces the statistical-guarantee side of the paper's Section 4: for
a (model, attack, Byzantine-fraction, aggregator) cell, run ``reps``
full replications — simulate sharded data, run RCSL under attack,
compute plug-in CIs under the *same* attack on the reported statistics
(``repro.infer.sandwich``), and record whether each coordinate of
theta* landed inside its interval — then report empirical coverage,
mean CI width, and RMSE.

The whole cell is ONE compiled program (DESIGN.md §9): replications are
``jax.lax.map``-batched (an inner ``vmap`` over ``batch_size`` reps per
scan step — vectorized work, bounded memory, zero per-rep Python
dispatch), and with a mesh they are additionally ``shard_map``-sharded
over the worker axis, each device running its own ``reps / W`` slice of
keys with no cross-device communication until the host-side summary.

``benchmarks/inference.py`` drives this over the paper grid and commits
``BENCH_inference.json``; ``tests/test_infer.py`` runs a small-rep cell
and checks coverage against the nominal level.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core import rcsl as R
from ..core.estimator import Estimator
from .sandwich import infer

__all__ = ["CoverageCell", "coverage_run"]


class CoverageCell(NamedTuple):
    """Raw per-replication outcomes of one coverage cell.

    covered: ``[reps, p]`` bool — theta*_l inside [lower_l, upper_l].
    width:   ``[reps, p]`` CI widths.
    err:     ``[reps, p]`` estimation errors theta_hat - theta*.
    """

    covered: jnp.ndarray
    width: jnp.ndarray
    err: jnp.ndarray

    def summary(self) -> dict:
        """Host-side scalars for tables / BENCH_inference.json."""
        return {
            "coverage": float(jnp.mean(self.covered)),
            "coverage_per_coord": [float(c)
                                   for c in jnp.mean(self.covered, axis=0)],
            "mean_width": float(jnp.mean(self.width)),
            "rmse": float(jnp.sqrt(jnp.mean(self.err ** 2))),
            "reps": int(self.covered.shape[0]),
        }


def coverage_run(
    model: str = "linear",
    attack: str = "gaussian",
    alpha: float = 0.1,
    estimator: Union[str, Estimator] = "vrmom",
    K: int = 10,
    level: float = 0.95,
    reps: int = 200,
    N_per_machine: int = 200,
    m_workers: int = 100,
    p: int = 5,
    rounds: int = 6,
    mu_x: float = 0.0,
    labelflip: bool = False,
    simultaneous: bool = False,
    seed: int = 0,
    batch_size: int = 16,
    mesh=None,
    rep_axis: str = "data",
    reduce_backend: str = "direct",
    consensus=None,
    fault_plan=None,
    assumed_alpha: Optional[float] = None,
) -> CoverageCell:
    """Run one fully-compiled coverage cell; see module docstring.

    ``mesh``/``rep_axis``: when given (and the axis is non-trivial) the
    replication axis is shard_map-sharded over it — ``reps`` must be
    divisible by the axis size. Without a mesh the same program runs on
    one device.

    ``reduce_backend="consensus"`` runs every RCSL round's aggregation
    through the peer-to-peer consensus emulation (DESIGN.md §13) with
    the given ``dist.consensus.ConsensusConfig`` / ``dist.faults.
    FaultPlan`` — the statistical cell under the decentralized wire,
    optionally with message loss and crashes injected inside each
    replication.

    ``assumed_alpha``: the contamination fraction the *analyst* plugs
    into the CI inflation, independent of the true ``alpha`` driving
    the attack (``infer``'s regime-matrix knob, DESIGN.md §14).
    ``None`` keeps the legacy oracle behavior (assume the truth).
    """
    theta_star = R.paper_theta_star(p)
    problem = (R.LinearRegressionProblem() if model == "linear"
               else R.LogisticRegressionProblem())

    def one_rep(key):
        kd, kr, ks = jax.random.split(key, 3)
        shards = R.make_shards(kd, N_per_machine=N_per_machine,
                               m_workers=m_workers, p=p,
                               theta_star=theta_star, model=model, mu_x=mu_x)
        theta_hat, _ = R.rcsl(problem, shards, kr, alpha=alpha, attack=attack,
                              aggregator=estimator, K=K, rounds=rounds,
                              labelflip=labelflip,
                              reduce_backend=reduce_backend,
                              consensus=consensus, fault_plan=fault_plan)
        shards_rep, stat_attack = shards, attack
        if labelflip:
            # Label-flip Byzantine machines report *honest* statistics
            # computed on flipped-label data (paper 4.2.2) — model that
            # by flipping their shard labels before machine_stats. The
            # flipped shards ARE the Byzantine reports, so no registry
            # attack is layered on top (rcsl's labelflip branch ignores
            # `attack` for the same reason).
            mask = R.attacks.byzantine_mask(m_workers + 1, alpha)
            shards_rep = R.Shards(
                X=shards.X,
                Y=jnp.where(mask[:, None], 1.0 - shards.Y, shards.Y))
            stat_attack = "none"
        res = infer(problem, shards_rep, theta_hat, estimator=estimator, K=K,
                    level=level, simultaneous=simultaneous,
                    alpha=alpha, attack=stat_attack, key=ks,
                    assumed_alpha=assumed_alpha)
        covered = jnp.logical_and(res.ci.lower <= theta_star,
                                  theta_star <= res.ci.upper)
        return covered, res.ci.upper - res.ci.lower, theta_hat - theta_star

    def run_keys(keys):
        return jax.lax.map(one_rep, keys, batch_size=batch_size)

    keys = jax.random.split(jax.random.PRNGKey(seed), reps)
    if mesh is not None and int(mesh.shape[rep_axis]) > 1:
        W = int(mesh.shape[rep_axis])
        if reps % W:
            raise ValueError(f"reps={reps} not divisible by the {W}-way "
                             f"mesh axis {rep_axis!r}")
        spec = P(rep_axis)
        keys = jax.device_put(keys, NamedSharding(mesh, spec))
        # Independent replications: each shard maps its own key slice;
        # no collectives — the rep axis is embarrassingly parallel.
        run = shard_map(run_keys, mesh=mesh,
                        in_specs=spec, out_specs=(spec, spec, spec),
                        check_rep=False)
        covered, width, err = jax.jit(run)(keys)
    else:
        covered, width, err = jax.jit(run_keys)(keys)
    return CoverageCell(covered=covered, width=width, err=err)
