"""Robust aggregators over a worker axis.

Every aggregator maps ``[m+1, ...] -> [...]`` (worker axis 0 by
convention) and is usable both on raw vectors (statistical experiments)
and on flattened gradient shards (distributed training — see
``repro.dist.robust_reduce``).

Implemented: mean, coordinate-wise median (MOM), VRMOM (the paper's
contribution), trimmed mean (Yin et al. 2018), geometric median (Feng et
al. 2014; Weiszfeld iterations), Krum (Blanchard et al. 2017).

These are the ``backend="jnp"`` execution functions of the unified
``core.estimator.Estimator`` layer (DESIGN.md §7) — the single dispatch
site for every robust-aggregation call in the repo. Use an Estimator
rather than calling these directly from subsystem code.
"""
from __future__ import annotations

import warnings
from typing import Callable

import jax
import jax.numpy as jnp

from . import vrmom as _v

Aggregator = Callable[[jnp.ndarray], jnp.ndarray]

__all__ = [
    "mean",
    "median",
    "trimmed_mean",
    "weiszfeld",
    "geometric_median",
    "krum",
    "vrmom",
    "REGISTRY",
]


def mean(x, axis: int = 0):
    return jnp.mean(x, axis=axis)


def median(x, axis: int = 0):
    return jnp.median(x, axis=axis)


def trimmed_mean(x, beta: float = 0.1, axis: int = 0):
    """Coordinate-wise beta-trimmed mean: drop the beta fraction at each end.

    ``int(beta*m) == 0`` trims nothing and the "trimmed" mean is the
    plain mean — zero robustness. That is almost always a configuration
    mistake (e.g. beta=0.1 at m=8), so it warns; ``Estimator.validate``
    upgrades it to a trace-time error.
    """
    m = x.shape[axis]
    k = int(beta * m)
    if k == 0:
        warnings.warn(
            f"trimmed_mean: beta={beta} trims int({beta}*{m}) = 0 rows per "
            f"end — degenerating to the NON-robust mean. Raise beta to at "
            f"least {1.0 / m:.4g}.", RuntimeWarning, stacklevel=2)
    xs = jnp.sort(x, axis=axis)
    sl = [slice(None)] * x.ndim
    sl[axis] = slice(k, m - k if m - k > k else k + 1)
    return jnp.mean(xs[tuple(sl)], axis=axis)


def weiszfeld(flat, pi, iters: int = 8, eps: float = 1e-8):
    """Weighted Weiszfeld iteration on a flat ``[m, C]`` stack.

    ``pi`` [m] are prior row weights; the fixed point is the minimizer
    of ``sum_i pi_i * ||y - x_i||``. With ``pi = ones`` this is the
    plain geometric median — ``geometric_median`` and the adaptive
    ``auto_gm`` tier (core.adaptive) share this exact body, so the
    honest regime (all weights exactly 1.0) is bit-identical between
    them by construction.
    """
    pi = pi.astype(flat.dtype)
    y = jnp.sum(flat * pi[:, None], axis=0) / jnp.sum(pi)

    def body(y, _):
        d = jnp.sqrt(jnp.sum((flat - y) ** 2, axis=-1) + eps)
        w = pi / d
        y = jnp.sum(flat * w[:, None], axis=0) / jnp.sum(w)
        return y, None

    y, _ = jax.lax.scan(body, y, None, length=iters)
    return y


def geometric_median(x, iters: int = 8, eps: float = 1e-8, axis: int = 0):
    """Geometric median over workers via Weiszfeld iterations.

    Treats each worker's row as a vector in R^(rest); returns [rest].
    """
    x = jnp.moveaxis(x, axis, 0)
    m = x.shape[0]
    flat = x.reshape(m, -1)
    y = weiszfeld(flat, jnp.ones((m,), flat.dtype), iters=iters, eps=eps)
    return y.reshape(x.shape[1:])


def krum(x, n_byzantine: int = 0, axis: int = 0):
    """Krum: select the worker closest to its m - f - 2 nearest neighbours."""
    x = jnp.moveaxis(x, axis, 0)
    m = x.shape[0]
    flat = x.reshape(m, -1)
    d2 = jnp.sum((flat[:, None, :] - flat[None, :, :]) ** 2, axis=-1)
    d2 = d2 + jnp.eye(m) * jnp.inf  # exclude self
    k = max(m - n_byzantine - 2, 1)
    nearest = jnp.sort(d2, axis=1)[:, :k]
    scores = jnp.sum(nearest, axis=1)
    idx = jnp.argmin(scores)
    return flat[idx].reshape(x.shape[1:])


def vrmom(x, K: int = 10, scale="mad", master_samples=None, axis: int = 0):
    return _v.vrmom(x, K=K, axis=axis, scale=scale, master_samples=master_samples)


# Enumeration only (tests, docs). Dispatch goes through
# core.estimator.Estimator — there is deliberately no get() here.
REGISTRY = {
    "mean": mean,
    "median": median,
    "mom": median,
    "trimmed_mean": trimmed_mean,
    "geometric_median": geometric_median,
    "krum": krum,
    "vrmom": vrmom,
}
