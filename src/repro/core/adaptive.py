"""Adaptive aggregation tier: estimators that estimate alpha instead of
assuming it (DESIGN.md §14).

The fixed estimators (§7) are calibrated for a *known* contamination
fraction; the omniscient attacks (``core.attacks``: alie / ipm / mimic)
land their payloads inside the honest spread, where the §11 MAD-z
suspicion census is blind and a fixed-K VRMOM keeps its honest-regime
bias/variance trade-off while the contamination drags it. This module
adds the online layer:

* ``census`` — a per-stack worker census combining two orthogonal
  signals: the §11 robust z-score over row deviations (exact against
  loud attacks) and a *duplicate-multiplicity* census (exact against
  coordinated attacks, whose Byzantine rows are bitwise-identical
  copies of one payload while honest continuous rows never collide).
  Majority duplicate clusters are exempt — the serve wire's honest
  replicas are deliberately bit-identical (DESIGN.md §12).
* ``estimate_alpha`` — the censused contamination estimate
  ``alpha_hat`` in ``[0, 0.5)``; exactly ``0.0`` on honest stacks.
* ``auto_gm`` — Weiszfeld-iterated geometric median with online
  per-worker weights (blades-style AutoGM). Shares the weighted
  Weiszfeld body with ``aggregators.geometric_median``; honest stacks
  produce all-ones weights, so the honest output is bit-identical to
  the plain geometric median by construction.
* ``vrmom_adaptive`` — imputes censused rows at the coordinatewise
  median, then selects VRMOM's K from a *static* ladder by
  ``alpha_hat`` (branchless ``jnp.where`` over precomputed candidates:
  the ``psi_sum``/``deltas`` tables stay host-side ``lru_cache``-d per
  static int K). ``alpha_hat == 0`` selects the configured K on the
  unmodified stack — bit-identical to fixed-K ``vrmom``.
* ``AdaptiveState`` / ``apply_adaptive`` — momentum-smoothed
  aggregation state (EMA per-worker weights + aggregate momentum)
  threaded as an *explicit carry*: jit-pure, no Python state, enforced
  by lint rule RL211.

Everything here is a pure function of its operands; the only module
globals are immutable constants.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import aggregators as _agg
from .vrmom import mad_scale, mom, vrmom

__all__ = [
    "StackCensus",
    "AdaptiveState",
    "census",
    "estimate_alpha",
    "worker_weights",
    "auto_gm",
    "vrmom_adaptive",
    "select_k",
    "k_ladder",
    "init_state",
    "apply_adaptive",
]

# Suspicion-score convention mirrored from obs.diag (§11): same robust
# z-score, same threshold, same relative floor — test_regimes pins the
# parity so the census and the telemetry never drift apart.
Z_THRESH = 4.0
REL_FLOOR = 0.05

# Residual trust weight for rows the z-census flags as loud outliers.
SUSPECT_WEIGHT = 1e-3

# A "loud" row must ALSO deviate by a multiple of the typical honest
# deviation, not just clear the z threshold: honest rows concentrate at
# dev/mom(dev) = 1 + O(1/sqrt(C)), so an honest stack cannot produce a
# 1.5x row even at seeds where the MAD-z alone has a false positive —
# this is what makes the honest-regime bit-identity guarantee hold
# unconditionally rather than with high probability.
LOUD_RATIO = 1.5

# alpha_hat cutoffs for the static K ladder: (<= first -> configured K,
# <= second -> K//2, above -> K=1). At alpha_hat = 0 the first branch
# is taken exactly, preserving fixed-K bit-identity.
K_LADDER_THRESHOLDS = (0.02, 0.2)

# Relative pairwise-distance threshold for the duplicate census. Rows
# of one coordinated payload are bitwise-identical (D2 == 0.0 exactly);
# honest continuous rows sit at the stack's typical pairwise scale.
DUP_REL_TOL = 1e-10


class StackCensus(NamedTuple):
    """Per-stack worker census (W = rows on the worker axis)."""

    z: jax.Array             # [W] f32 — §11 robust z-score of row deviation
    cluster_size: jax.Array  # [W] i32 — duplicate-cluster multiplicity (>= 1)
    suspected: jax.Array     # [W] bool — z-outlier OR minority duplicate
    alpha_hat: jax.Array     # []  f32 — censused contamination fraction
    weights: jax.Array       # [W] f32 — instantaneous trust weights
    center: jax.Array        # [C] f32 — coordinatewise median of the stack


class AdaptiveState(NamedTuple):
    """Explicit momentum-smoothed aggregation carry (RL211: adaptive
    state is jit-pure data threaded by the caller, never module
    state)."""

    weights: jax.Array    # [W] f32 — EMA per-worker trust weights
    momentum: jax.Array   # [C] f32 — EMA of the flat aggregate
    step: jax.Array       # []  i32 — update count
    alpha_hat: jax.Array  # []  f32 — EMA contamination estimate


def _flat32(x, axis: int):
    """Move the worker axis first and flatten to f32 ``[W, C]``."""
    x = jnp.moveaxis(x, axis, 0)
    return x.reshape(x.shape[0], -1).astype(jnp.float32), x.shape[1:]


def census(flat) -> StackCensus:
    """Worker census of a flat ``[W, C]`` stack (f32).

    Signal 1 (loud attacks): the §11 robust z-score of each row's L2
    deviation from the coordinatewise median center. Signal 2
    (coordinated attacks): duplicate multiplicity — pairwise squared
    distances at 0 relative to the stack's median pairwise distance
    mark rows sharing one payload; clusters holding more than half the
    stack are the honest consensus (serve replicas) and stay exempt.
    Honest continuous stacks trip neither signal, so ``suspected`` is
    all-false and ``alpha_hat`` is exactly ``0.0``.
    """
    w = flat.shape[0]
    center = jnp.median(flat, axis=0)
    dev = jnp.sqrt(jnp.sum(jnp.square(flat - center[None]), axis=-1))
    c_dev = mom(dev, axis=0)
    scale = mad_scale(dev, axis=0, center=c_dev)
    z = (dev - c_dev) / (scale + REL_FLOOR * c_dev + 1e-12)
    z_sus = (z > Z_THRESH) & (dev > LOUD_RATIO * c_dev)

    d2 = jnp.sum(jnp.square(flat[:, None, :] - flat[None, :, :]), axis=-1)
    dup = d2 <= (DUP_REL_TOL * jnp.median(d2) + 1e-30)
    csize = jnp.sum(dup.astype(jnp.int32), axis=1)
    dup_sus = (csize > 1) & (csize <= w // 2)

    suspected = z_sus | dup_sus
    alpha_hat = jnp.clip(jnp.mean(suspected.astype(jnp.float32)), 0.0, 0.499)
    cs = csize.astype(jnp.float32)
    weights = (jnp.where(dup_sus, 1.0 / cs, 1.0)
               * jnp.where(z_sus, SUSPECT_WEIGHT, 1.0))
    return StackCensus(z=z, cluster_size=csize, suspected=suspected,
                       alpha_hat=alpha_hat, weights=weights, center=center)


def estimate_alpha(x, axis: int = 0) -> jax.Array:
    """Online contamination estimate over a stacked array: the censused
    fraction of suspected rows, in ``[0, 0.5)``; ``0.0`` exactly on
    honest stacks."""
    flat, _ = _flat32(x, axis)
    return census(flat).alpha_hat


def worker_weights(x, axis: int = 0) -> jax.Array:
    """[W] instantaneous per-worker trust weights (all exactly 1.0 on
    honest stacks): minority duplicate clusters share one vote
    (``1/cluster_size``), loud z-outliers keep ``SUSPECT_WEIGHT``."""
    flat, _ = _flat32(x, axis)
    return census(flat).weights


def auto_gm(x, axis: int = 0, iters: int = 8, eps: float = 1e-8,
            weights=None):
    """Auto-weighted geometric median: weighted Weiszfeld under the
    census trust weights (or caller-provided ``weights`` [W], e.g. the
    EMA-smoothed state). Honest stacks give all-ones weights and a
    result bit-identical to ``aggregators.geometric_median``."""
    flat, rest = _flat32(x, axis)
    pi = census(flat).weights if weights is None else weights
    y = _agg.weiszfeld(flat, pi, iters=iters, eps=eps)
    return y.reshape(rest).astype(x.dtype)


def k_ladder(K: int) -> Tuple[int, ...]:
    """Static K candidates, largest first: configured K for the honest
    regime, K//2 for moderate contamination, K=1 for heavy
    contamination (``vrmom_correction_bound`` grows with K, so the
    ladder trades variance-reduction for contamination bias as
    ``alpha_hat`` rises). Deduplicated, order-preserving."""
    out = []
    for k in (int(K), max(int(K) // 2, 1), 1):
        if k not in out:
            out.append(k)
    return tuple(out)


def _select(alpha_hat, candidates):
    """Branchless ladder select: candidates[i] for alpha_hat below
    K_LADDER_THRESHOLDS[i], last candidate above them all."""
    out = candidates[-1]
    for thr, cand in zip(reversed(K_LADDER_THRESHOLDS[:len(candidates) - 1]),
                         reversed(candidates[:-1])):
        out = jnp.where(alpha_hat <= thr, cand, out)
    return out


def select_k(alpha_hat, K: int) -> jax.Array:
    """[] f32 — the ladder rung ``vrmom_adaptive`` runs at for this
    ``alpha_hat`` (telemetry mirror of the internal select)."""
    lad = k_ladder(K)
    return _select(alpha_hat, tuple(jnp.float32(k) for k in lad))


def vrmom_adaptive(x, K: int = 10, axis: int = 0):
    """Adaptive-K VRMOM: census the stack, impute suspected rows at the
    coordinatewise median, run VRMOM at every static ladder rung, and
    select the rung by ``alpha_hat`` (branchless — the per-K
    ``deltas``/``psi_sum`` tables stay host-side cached statics).

    ``alpha_hat == 0`` (honest stack) imputes nothing and selects the
    configured K: bit-identical to fixed-K ``vrmom``.
    """
    flat, rest = _flat32(x, axis)
    cen = census(flat)
    x_adj = jnp.where(cen.suspected[:, None], cen.center[None, :], flat)
    outs = tuple(vrmom(x_adj, K=k, axis=0) for k in k_ladder(K))
    y = _select(cen.alpha_hat, outs)
    return y.reshape(rest).astype(x.dtype)


def init_state(n_workers: int, dim: int) -> AdaptiveState:
    """Honest-prior carry: unit trust, zero momentum, step 0."""
    return AdaptiveState(
        weights=jnp.ones((n_workers,), jnp.float32),
        momentum=jnp.zeros((dim,), jnp.float32),
        step=jnp.zeros((), jnp.int32),
        alpha_hat=jnp.zeros((), jnp.float32),
    )


def apply_adaptive(method: str, x, state: AdaptiveState, axis: int = 0, *,
                   K: int = 10, weights_beta: float = 0.5,
                   momentum: float = 0.0
                   ) -> Tuple[jax.Array, AdaptiveState]:
    """One stateful adaptive aggregate: ``(aggregate, new_state)``.

    Census the stack, EMA the per-worker trust weights
    (``w <- (1-beta)*w + beta*w_inst``; unit weights are a fixed point,
    so the honest regime stays bit-identical to the stateless apply),
    aggregate under the smoothed weights, and optionally momentum-smooth
    the flat aggregate (bias-corrected EMA; ``momentum=0.0`` returns
    the instantaneous aggregate exactly). The state is an explicit
    carry — this function is jit-pure (RL211).
    """
    if method not in ("auto_gm", "vrmom_adaptive"):
        raise ValueError(f"not an adaptive method: {method!r}")
    flat, rest = _flat32(x, axis)
    cen = census(flat)
    beta = jnp.float32(weights_beta)
    w_ema = (1.0 - beta) * state.weights + beta * cen.weights
    a_ema = (1.0 - beta) * state.alpha_hat + beta * cen.alpha_hat
    if method == "auto_gm":
        agg = _agg.weiszfeld(flat, w_ema)
    else:
        sus = w_ema < 0.5
        x_adj = jnp.where(sus[:, None], cen.center[None, :], flat)
        outs = tuple(vrmom(x_adj, K=k, axis=0) for k in k_ladder(K))
        agg = _select(a_ema, outs)
    step = state.step + 1
    mu = jnp.float32(momentum)
    m_new = mu * state.momentum + (1.0 - mu) * agg
    if momentum:
        out = m_new / (1.0 - mu ** step.astype(jnp.float32))
    else:
        out = agg
    new_state = AdaptiveState(weights=w_ema, momentum=m_new, step=step,
                              alpha_hat=a_ema)
    return out.reshape(rest).astype(x.dtype), new_state
