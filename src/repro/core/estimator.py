"""Unified robust-aggregation layer: one backend-dispatched Estimator.

The paper's entire contribution is one operation — coordinate-wise
robust aggregation over a worker axis (VRMOM eq. 7; trimmed mean per Yin
et al. 2018) — and every subsystem (dist RRS, the robust backward, the
replicated serving path, the statistical experiments) needs exactly that
operation with different performance constraints. ``Estimator`` is the
single dispatch site (DESIGN.md §7): a hashable spec

    Estimator(method, K=10, beta=0.1, backend="auto")

with ``apply(x, axis=0)`` mapping ``[m, ...] -> [...]``. Backends:

* ``"jnp"``    — the plain :mod:`repro.core.aggregators` functions.
  The only backend for the whole-vector estimators (geometric median,
  Krum), and the reference semantics for everything else.
* ``"ref"``    — the fused single-reshape jnp oracles in
  :mod:`repro.kernels.ref` (coordinate-wise methods only).
* ``"pallas"`` — the fused one-pass kernel family in
  :mod:`repro.kernels.vrmom`: median / VRMOM / trimmed mean / mean all
  ride one odd-even sorting network over the worker axis in VMEM
  (interpret mode off-TPU, so the same path runs everywhere).
* ``"auto"``   — the fused Pallas kernel when the method supports it
  (the worker dim is always static under jit), ``kernels/ref``
  otherwise for coordinate-wise methods, ``jnp`` for whole-vector ones.

Specs are NamedTuples: usable as jit static arguments, as custom-VJP
nondiff arguments, and inside other static configs
(``serve.robust.RobustDecodeConfig``, ``dist.ctx.RobustBackwardState``).

Validation happens at trace time (shapes are static): ``validate(m)``
rejects a ``trimmed_mean`` whose ``int(beta*m) == 0`` (it would silently
degrade to the mean — the exact failure mode of beta=0.1 at m=8) and
``require_coordinatewise()`` rejects whole-vector estimators for the
chunked/RRS wire format, where aggregating a coordinate *shard* as if it
were a full vector would produce wrong results (DESIGN.md §7).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from . import aggregators as _A
from ..lint.hashguard import check_hashable_fields

__all__ = [
    "Estimator",
    "COORDINATEWISE_METHODS",
    "WHOLE_VECTOR_METHODS",
    "ADAPTIVE_METHODS",
    "METHODS",
    "BACKENDS",
]

# Coordinate-wise methods act independently per coordinate, so they can
# be sharded/chunked arbitrarily (the RRS wire format relies on this).
COORDINATEWISE_METHODS = ("mean", "median", "mom", "trimmed_mean", "vrmom")
# Whole-vector methods score/select entire worker rows; chunking them
# changes their semantics, so they are valid only on full vectors.
WHOLE_VECTOR_METHODS = ("geometric_median", "krum")
# Adaptive methods (DESIGN.md §14) census entire worker rows to
# estimate alpha online, then aggregate under the censused weights.
# Like the whole-vector tier they need full rows (never coordinate
# shards); unlike it their output is a plain coordinate-wise-shaped
# aggregate, so every wire that materializes full rows (serve logits,
# the symmetric-triangle stats wire, the full-stack auto wire) accepts
# them via ``require_stackable``.
ADAPTIVE_METHODS = ("auto_gm", "vrmom_adaptive")
METHODS = COORDINATEWISE_METHODS + WHOLE_VECTOR_METHODS + ADAPTIVE_METHODS
BACKENDS = ("auto", "jnp", "ref", "pallas")

# Methods the auto backend routes to the fused kernel: the ones whose
# order statistics ride the sorting network. The mean gains nothing from
# the kernel (one masked sum — BENCH_agg.json shows plain jnp/ref wins),
# so auto sends it to ref; backend="pallas" still accepts it explicitly.
_FUSED_METHODS = frozenset(("median", "mom", "trimmed_mean", "vrmom"))


class Estimator(NamedTuple):
    """Robust-aggregation spec: method + knobs + execution backend.

    method:      one of ``METHODS`` ("mom" is an alias of "median").
    K:           VRMOM quantile levels (ignored by other methods).
    beta:        trimmed-mean trim fraction per end (ignored otherwise).
    backend:     one of ``BACKENDS``; see module docstring.
    n_byzantine: Krum's assumed corrupted-row count (ignored otherwise).
    interpret:   force Pallas interpret mode (None = auto: interpret
                 off-TPU). Test/bench knob only.
    """

    method: str = "vrmom"
    K: int = 10
    beta: float = 0.1
    backend: str = "auto"
    n_byzantine: int = 0
    interpret: Optional[bool] = None

    # -- construction -------------------------------------------------------

    @classmethod
    def coerce(cls, spec: Union[str, "Estimator"], **defaults) -> "Estimator":
        """Normalize a method name or an Estimator into an Estimator.

        ``defaults`` are constructor overrides applied only when coercing
        from a string — an explicit Estimator is taken verbatim.
        """
        if isinstance(spec, cls):
            return spec
        if isinstance(spec, str):
            return cls(method=spec, **defaults)
        raise TypeError(
            f"expected a method name or an Estimator, got {type(spec)!r}")

    # -- predicates ---------------------------------------------------------

    @property
    def coordinatewise(self) -> bool:
        return self.method in COORDINATEWISE_METHODS

    def require_coordinatewise(self, where: str = "chunked/RRS aggregation"):
        """Whole-vector estimators cannot ride the coordinate-wise wire
        format: RRS hands each worker a coordinate *shard*, and scoring
        shards as if they were full vectors silently yields wrong
        results. Reject at trace time instead."""
        if not self.coordinatewise:
            raise ValueError(
                f"estimator {self.method!r} is a whole-vector estimator "
                f"(selects/scores entire worker rows) and cannot be used "
                f"for {where}: the coordinate-wise wire format would hand "
                f"it shards of coordinates and produce wrong shards. Use "
                f"one of {COORDINATEWISE_METHODS} instead.")
        return self

    @property
    def adaptive(self) -> bool:
        return self.method in ADAPTIVE_METHODS

    def require_stackable(self, where: str = "full-stack aggregation"):
        """Gate for wires that materialize complete worker rows (serve
        replica logits, the symmetric stats triangle, the flattened
        full-stack wire): coordinate-wise and adaptive estimators both
        produce a per-coordinate aggregate there. Whole-vector
        *selectors* (geometric_median, krum) stay rejected — they are
        served by the jnp backend directly, not by these wires."""
        if not (self.coordinatewise or self.adaptive):
            raise ValueError(
                f"estimator {self.method!r} cannot be used for {where}: "
                f"only coordinate-wise ({COORDINATEWISE_METHODS}) and "
                f"adaptive ({ADAPTIVE_METHODS}) estimators aggregate a "
                f"full row stack into a per-coordinate result; "
                f"{self.method!r} is a whole-vector selector.")
        return self

    def validate(self, m: int) -> "Estimator":
        """Trace-time validation of the spec against a worker count."""
        if self.method not in METHODS:
            raise ValueError(
                f"unknown estimator method {self.method!r}; "
                f"known: {METHODS}")
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; known: {BACKENDS}")
        if self.backend in ("ref", "pallas"):
            self.require_coordinatewise(f"backend={self.backend!r}")
        if m < 1:
            raise ValueError(f"worker axis must be non-empty, got m={m}")
        if self.method == "trimmed_mean":
            k = int(self.beta * m)
            if k == 0:
                raise ValueError(
                    f"trimmed_mean with beta={self.beta} trims "
                    f"int({self.beta}*{m}) = 0 rows per end and silently "
                    f"degrades to the mean (no robustness). Raise beta to "
                    f"at least {1.0 / m:.4g} or use another method.")
            if m - 2 * k < 1:
                raise ValueError(
                    f"trimmed_mean with beta={self.beta} trims "
                    f"2*{k} >= m={m} rows: nothing left to average")
        if self.method in ("vrmom", "vrmom_adaptive") and self.K < 1:
            raise ValueError(f"{self.method} needs K >= 1, got K={self.K}")
        return self

    # -- dispatch -----------------------------------------------------------

    def resolve_backend(self) -> str:
        """The concrete backend ``apply`` will run ("auto" resolved).

        Worker dims are always static under jit, so "auto" picks the
        fused Pallas kernel whenever the method has one (off-TPU it runs
        in interpret mode — same code path, host execution), the fused
        ref oracle for any fused-less coordinate-wise method, and jnp
        for whole-vector estimators.
        """
        if self.backend != "auto":
            return self.backend
        if not self.coordinatewise:
            return "jnp"
        if self.method in _FUSED_METHODS:
            return "pallas"
        return "ref"

    def apply(self, x, axis: int = 0):
        """Aggregate ``x`` over ``axis``: ``[.., m, ..] -> [..]``.

        Validates the spec against the (static) worker count, resolves
        the backend, and runs the estimator. Computation is f32
        internally on the fused backends; output dtype matches input.
        """
        m = x.shape[axis]
        self.validate(m)
        backend = self.resolve_backend()
        if backend == "jnp":
            return self._apply_jnp(x, axis)
        self.require_coordinatewise(f"backend={backend!r}")
        if axis != 0:
            x = jnp.moveaxis(x, axis, 0)
        shape = x.shape[1:]
        flat = x.reshape(m, -1)
        if backend == "ref":
            out = self._apply_ref(flat)
        else:
            from ..kernels.vrmom import aggregate_pallas

            out = aggregate_pallas(flat, method=self.method, K=self.K,
                                   beta=self.beta, interpret=self.interpret)
        return out.reshape(shape)

    def apply_sample(self, x, top_k: int = 0, with_agg: bool = True):
        """Fused aggregation + sampling tail over a ``[m, B, V]`` stack.

        The fused-tail dispatch rides the same ``backend=`` pattern as
        ``apply``: when the resolved backend is ``"pallas"`` and the
        method has a fused kernel, aggregation and the sampling epilogue
        (greedy argmax for ``top_k == 0``, top-k selection otherwise)
        run as ONE Pallas dispatch on the VMEM-resident aggregate
        (DESIGN.md §12); every other backend computes the aggregate via
        ``apply`` and runs the identical jnp tail, so tokens agree
        across backends (bit-identical for greedy).

        Returns ``(agg, tok[B] int32)`` for greedy or
        ``(agg, topv [B, k], topi [B, k])`` for top-k; ``agg`` is None
        when ``with_agg=False`` on the fused path (the [B, V] aggregate
        write is skipped entirely).
        """
        if x.ndim != 3:
            raise ValueError(
                f"apply_sample wants [m, B, V] logit stacks, got {x.shape}")
        m = x.shape[0]
        self.validate(m)
        backend = self.resolve_backend()
        if backend == "pallas" and self.method in _FUSED_METHODS:
            from ..kernels.vrmom import aggregate_sample_pallas

            return aggregate_sample_pallas(
                x, method=self.method, K=self.K, beta=self.beta,
                top_k=top_k, interpret=self.interpret, with_agg=with_agg)
        agg = self.apply(x, axis=0)
        if top_k == 0:
            return agg, jnp.argmax(agg, axis=-1).astype(jnp.int32)
        topv, topi = jax.lax.top_k(agg, top_k)
        return agg, topv, topi.astype(jnp.int32)

    def init_adaptive_state(self, n_workers: int, dim: int):
        """Fresh honest-prior :class:`repro.core.adaptive.AdaptiveState`
        carry for ``apply_adaptive`` (adaptive methods only)."""
        from . import adaptive as _AD

        if not self.adaptive:
            raise ValueError(
                f"estimator {self.method!r} carries no adaptive state; "
                f"adaptive methods: {ADAPTIVE_METHODS}")
        return _AD.init_state(n_workers, dim)

    def apply_adaptive(self, x, state, axis: int = 0, *,
                       weights_beta: float = 0.5, momentum: float = 0.0):
        """Stateful adaptive aggregate: ``(aggregate, new_state)``.

        The momentum-smoothed per-worker weights ride ``state`` as an
        explicit jit-pure carry (RL211) — thread the returned state
        into the next call. Stateless ``apply`` on an honest stack and
        ``apply_adaptive`` from a fresh state agree bit-for-bit (unit
        weights are an EMA fixed point and ``momentum=0.0`` is exact).
        """
        from . import adaptive as _AD

        if not self.adaptive:
            raise ValueError(
                f"estimator {self.method!r} carries no adaptive state; "
                f"adaptive methods: {ADAPTIVE_METHODS}")
        self.validate(x.shape[axis])
        return _AD.apply_adaptive(self.method, x, state, axis=axis,
                                  K=self.K, weights_beta=weights_beta,
                                  momentum=momentum)

    def apply_with_diag(self, x, axis: int = 0):
        """``apply`` plus per-worker diagnostics (DESIGN.md §11).

        Returns ``(aggregate, obs.diag.AggDiagnostics)``: the aggregate
        is bit-identical to ``apply(x, axis)`` (the diag pass reads the
        stack, it never feeds back), and the diagnostics are fixed-shape
        arrays safe as jit aux outputs — per-worker deviation scores, a
        suspected-Byzantine mask, the online effective-alpha estimate,
        and pre/post-aggregation norms.
        """
        from ..obs import diag as _D

        agg = self.apply(x, axis)
        return agg, _D.diagnose(x, agg, axis=axis)

    def _apply_jnp(self, x, axis: int):
        if self.method == "mean":
            return _A.mean(x, axis=axis)
        if self.method in ("median", "mom"):
            return _A.median(x, axis=axis)
        if self.method == "trimmed_mean":
            return _A.trimmed_mean(x, beta=self.beta, axis=axis)
        if self.method == "vrmom":
            return _A.vrmom(x, K=self.K, axis=axis)
        if self.method == "geometric_median":
            return _A.geometric_median(x, axis=axis)
        if self.method in ADAPTIVE_METHODS:
            from . import adaptive as _AD

            if self.method == "auto_gm":
                return _AD.auto_gm(x, axis=axis)
            return _AD.vrmom_adaptive(x, K=self.K, axis=axis)
        return _A.krum(x, n_byzantine=self.n_byzantine, axis=axis)

    def _apply_ref(self, flat):
        from ..kernels import ref as _R

        if self.method == "mean":
            return _R.ref_mean(flat)
        if self.method in ("median", "mom"):
            return _R.ref_mom(flat)
        if self.method == "trimmed_mean":
            return _R.ref_trimmed_mean(flat, beta=self.beta)
        return _R.ref_vrmom(flat, K=self.K)


# Construction-time hashability backstop (reprolint RL004): an Estimator
# carrying an unhashable field (a list of betas, an array-valued K)
# would fail — or worse, silently retrace — at every jit boundary it
# keys. typing.NamedTuple forbids overriding __new__ in the class body,
# so the guard wraps it post-definition. (NB: ``_replace`` uses the raw
# tuple constructor and bypasses this; the trace auditor's recompile
# guard covers that residual path.)
_orig_new = Estimator.__new__


def _checked_new(cls, *args, **kwargs):
    self = _orig_new(cls, *args, **kwargs)
    check_hashable_fields(self)
    return self


Estimator.__new__ = _checked_new
