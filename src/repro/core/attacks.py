"""Byzantine attack models from Section 4 of the paper (plus extras).

An attack transforms the stacked honest messages ``v`` of shape
``[m+1, ...]`` into corrupted messages, replacing the rows selected by a
boolean mask. Machine 0 (the master H0) is never corrupted, matching the
paper's setup. Attacks are pure functions of (key, values, mask) so they
compose with vmap/jit.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

Attack = Callable[[jax.Array, jnp.ndarray, jnp.ndarray], jnp.ndarray]

__all__ = [
    "byzantine_mask",
    "gaussian",
    "omniscient",
    "alie",
    "bitflip",
    "signflip",
    "zero",
    "wrong_value",
    "get",
    "REGISTRY",
]


def byzantine_mask(m_plus_1: int, alpha: float) -> jnp.ndarray:
    """Deterministic mask with floor(alpha * m) Byzantine workers.

    Row 0 is the master and never Byzantine (paper Definition 1 with the
    master assumed trusted). The last floor(alpha*m) workers are chosen;
    the estimators are permutation-invariant so the choice is WLOG.
    """
    m = m_plus_1 - 1
    n_byz = int(alpha * m)
    idx = jnp.arange(m_plus_1)
    return idx >= (m_plus_1 - n_byz)


def _apply(mask, honest, corrupt):
    mask = mask.reshape((-1,) + (1,) * (honest.ndim - 1))
    return jnp.where(mask, corrupt, honest)


def gaussian(key, v, mask, std: float = 200.0 ** 0.5):
    """Gaussian attack: replace messages by N(0, 200*I) draws (paper 4.1)."""
    noise = std * jax.random.normal(key, v.shape, v.dtype)
    return _apply(mask, v, noise)


def omniscient(key, v, mask, scale: float = 1e10):
    """Omniscient attack: scaled negative of the honest mean (paper 4.2(b))."""
    honest_mean = jnp.mean(v, axis=0, keepdims=True)
    return _apply(mask, v, -scale * jnp.broadcast_to(honest_mean, v.shape))


def alie(key, v, mask, z=None):
    """ALIE ("a little is enough", Baruch et al. 2019): Byzantine rows
    sit at ``honest_mean + z * honest_std`` per coordinate — inside the
    honest point cloud, so naive trimming cannot separate them, yet
    coordinated, so they drag every mean-like aggregate one-sided.

    ``z`` defaults to the paper's omniscient choice
    ``Phi^{-1}((n - m - s) / (n - m))`` with ``s = floor(n/2 + 1) - m``
    — the largest offset at which the corrupt rows still out-vote
    enough honest tail mass to capture the median. Honest statistics
    are computed over the unmasked rows only (the adversary observes
    honest messages, not its own payloads).
    """
    f32 = v.astype(jnp.float32)
    keep = (~mask).reshape((-1,) + (1,) * (v.ndim - 1)).astype(jnp.float32)
    n_h = jnp.maximum(jnp.sum(keep, axis=0), 1.0)
    mean = jnp.sum(f32 * keep, axis=0, keepdims=True) / n_h
    var = jnp.sum((f32 - mean) ** 2 * keep, axis=0, keepdims=True) / n_h
    std = jnp.sqrt(jnp.maximum(var, 0.0))
    if z is None:
        n = jnp.float32(v.shape[0])
        m = jnp.sum(mask.astype(jnp.float32))
        s = jnp.floor(n / 2.0 + 1.0) - m
        q = jnp.clip((n - m - s) / jnp.maximum(n - m, 1.0), 0.5, 1.0 - 1e-6)
        z = jax.scipy.special.ndtri(q)
    corrupt = (mean + z * std).astype(v.dtype)
    return _apply(mask, v, jnp.broadcast_to(corrupt, v.shape))


def bitflip(key, v, mask, n_dims: int = 5):
    """Bit-flip attack: flip the sign of the first ``n_dims`` coordinates."""
    if v.ndim == 1:
        return _apply(mask, v, -v)
    flip = jnp.where(jnp.arange(v.shape[-1]) < n_dims, -1.0, 1.0).astype(v.dtype)
    return _apply(mask, v, v * flip)


def signflip(key, v, mask, scale: float = 1.0):
    """Full sign flip (classic baseline)."""
    return _apply(mask, v, -scale * v)


def zero(key, v, mask):
    """Send zeros (drop-out / crash failure)."""
    return _apply(mask, v, jnp.zeros_like(v))


def wrong_value(key, v, mask, value: float = 100.0):
    """Wrong-value attack: Byzantine machines all report the same fixed
    constant. A one-sided, coordinated attack — unlike ``gaussian`` it
    does not average out across machines, so it stresses the median's
    contamination bias (and the CI coverage of ``repro.infer``)."""
    return _apply(mask, v, jnp.full_like(v, value))


REGISTRY = {
    "none": lambda key, v, mask: v,
    "gaussian": gaussian,
    "omniscient": omniscient,
    "alie": alie,
    "bitflip": bitflip,
    "signflip": signflip,
    "zero": zero,
    "wrong_value": wrong_value,
}


def get(name: str) -> Attack:
    return REGISTRY[name]
