"""Byzantine attack models from Section 4 of the paper (plus extras).

An attack transforms the stacked honest messages ``v`` of shape
``[m+1, ...]`` into corrupted messages, replacing the rows selected by a
boolean mask. Machine 0 (the master H0) is never corrupted, matching the
paper's setup. Attacks are pure functions of (key, values, mask) so they
compose with vmap/jit.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

Attack = Callable[[jax.Array, jnp.ndarray, jnp.ndarray], jnp.ndarray]

__all__ = [
    "byzantine_mask",
    "gaussian",
    "omniscient",
    "alie",
    "ipm",
    "mimic",
    "bitflip",
    "signflip",
    "zero",
    "wrong_value",
    "get",
    "REGISTRY",
    "OMNISCIENT_ATTACKS",
]


def byzantine_mask(m_plus_1: int, alpha: float) -> jnp.ndarray:
    """Deterministic mask with floor(alpha * m) Byzantine workers.

    Row 0 is the master and never Byzantine (paper Definition 1 with the
    master assumed trusted). The last floor(alpha*m) workers are chosen;
    the estimators are permutation-invariant so the choice is WLOG.
    """
    m = m_plus_1 - 1
    n_byz = int(alpha * m)
    idx = jnp.arange(m_plus_1)
    return idx >= (m_plus_1 - n_byz)


def _apply(mask, honest, corrupt):
    mask = mask.reshape((-1,) + (1,) * (honest.ndim - 1))
    return jnp.where(mask, corrupt, honest)


def gaussian(key, v, mask, std: float = 200.0 ** 0.5):
    """Gaussian attack: replace messages by N(0, 200*I) draws (paper 4.1)."""
    noise = std * jax.random.normal(key, v.shape, v.dtype)
    return _apply(mask, v, noise)


def omniscient(key, v, mask, scale: float = 1e10):
    """Omniscient attack: scaled negative of the honest mean (paper 4.2(b))."""
    honest_mean = jnp.mean(v, axis=0, keepdims=True)
    return _apply(mask, v, -scale * jnp.broadcast_to(honest_mean, v.shape))


def _honest_moments(v, mask):
    """Per-coordinate mean/std over the *unmasked* rows (the adversary
    observes honest messages, not its own payloads). Returns f32
    ``(mean, std)`` with keepdims on the row axis."""
    f32 = v.astype(jnp.float32)
    keep = (~mask).reshape((-1,) + (1,) * (v.ndim - 1)).astype(jnp.float32)
    n_h = jnp.maximum(jnp.sum(keep, axis=0), 1.0)
    mean = jnp.sum(f32 * keep, axis=0, keepdims=True) / n_h
    var = jnp.sum((f32 - mean) ** 2 * keep, axis=0, keepdims=True) / n_h
    return mean, jnp.sqrt(jnp.maximum(var, 0.0))


def alie(key, v, mask, z=None):
    """ALIE ("a little is enough", Baruch et al. 2019): Byzantine rows
    sit at ``honest_mean + z * honest_std`` per coordinate — inside the
    honest point cloud, so naive trimming cannot separate them, yet
    coordinated, so they drag every mean-like aggregate one-sided.

    ``z`` defaults to the paper's omniscient choice: with ``s =
    floor(n/2) + 1 - m`` honest rows to out-vote (the corrupt block
    plus the ``s`` honest values above it must capture the median),
    the target quantile of the ``n_h = n - m`` honest draws is the
    plotting position ``q = (n_h - s + 1) / (n_h + 1)`` and
    ``z = Phi^{-1}(q)``. The continuity-corrected ``+1`` keeps ``q``
    strictly inside (0.5, 1) for every n >= 2, and a floor
    ``z >= 0.2`` keeps the payload a genuine offset at the boundary
    sizes (n <= 4) where the quantile argument alone degenerates to
    the honest mean.
    """
    mean, std = _honest_moments(v, mask)
    if z is None:
        n = v.shape[0]
        m = jnp.sum(mask.astype(jnp.float32))
        n_h = jnp.maximum(jnp.float32(n) - m, 1.0)
        s = jnp.float32(n // 2 + 1) - m
        q = jnp.clip((n_h - s + 1.0) / (n_h + 1.0), 0.5, 1.0 - 1e-6)
        z = jnp.maximum(jax.scipy.special.ndtri(q), 0.2)
    corrupt = (mean + z * std).astype(v.dtype)
    return _apply(mask, v, jnp.broadcast_to(corrupt, v.shape))


def ipm(key, v, mask, eps: float = 0.5):
    """Inner-product manipulation (Xie et al. 2020): every Byzantine
    row reports ``-eps * honest_mean``, making the corrupt block's
    inner product with the honest direction negative while each
    individual coordinate stays at honest-mean scale. Small ``eps``
    is a stealth attack (the payload sits inside the honest spread);
    large ``eps`` degenerates to the loud ``omniscient`` attack.
    """
    mean, _ = _honest_moments(v, mask)
    corrupt = (-eps * mean).astype(v.dtype)
    return _apply(mask, v, jnp.broadcast_to(corrupt, v.shape))


def mimic(key, v, mask):
    """Coordinated mimic attack (Karimireddy et al. 2022): every
    Byzantine row replays the honest row farthest from the honest
    mean. Each payload is a *real* honest message — per-row outlier
    tests can never flag it — but the coordinated copies overweight
    one honest extreme, biasing mean-like aggregates while staying
    inside the honest support. Honest statistics and the argmax are
    computed over the unmasked rows only.
    """
    mean, _ = _honest_moments(v, mask)
    f32 = v.astype(jnp.float32)
    dev = jnp.sum((f32 - mean) ** 2,
                  axis=tuple(range(1, v.ndim)))  # [n] per-row deviation
    dev = jnp.where(mask, -jnp.inf, dev)  # adversary picks an honest victim
    victim = jnp.argmax(dev)
    corrupt = jnp.broadcast_to(v[victim][None], v.shape)
    return _apply(mask, v, corrupt)


def bitflip(key, v, mask, n_dims: int = 5):
    """Bit-flip attack: flip the sign of the first ``n_dims`` coordinates."""
    if v.ndim == 1:
        return _apply(mask, v, -v)
    flip = jnp.where(jnp.arange(v.shape[-1]) < n_dims, -1.0, 1.0).astype(v.dtype)
    return _apply(mask, v, v * flip)


def signflip(key, v, mask, scale: float = 1.0):
    """Full sign flip (classic baseline)."""
    return _apply(mask, v, -scale * v)


def zero(key, v, mask):
    """Send zeros (drop-out / crash failure)."""
    return _apply(mask, v, jnp.zeros_like(v))


def wrong_value(key, v, mask, value: float = 100.0):
    """Wrong-value attack: Byzantine machines all report the same fixed
    constant. A one-sided, coordinated attack — unlike ``gaussian`` it
    does not average out across machines, so it stresses the median's
    contamination bias (and the CI coverage of ``repro.infer``)."""
    return _apply(mask, v, jnp.full_like(v, value))


REGISTRY = {
    "none": lambda key, v, mask: v,
    "gaussian": gaussian,
    "omniscient": omniscient,
    "alie": alie,
    "ipm": ipm,
    "mimic": mimic,
    "bitflip": bitflip,
    "signflip": signflip,
    "zero": zero,
    "wrong_value": wrong_value,
}

# Attacks whose payload is a function of the observed honest stack
# (the adversary sees all honest updates before choosing its own).
# They share the oblivious zoo's (key, v, mask) contract, so they
# compose unchanged with dist.faults and the consensus pin-mask.
OMNISCIENT_ATTACKS = ("omniscient", "alie", "ipm", "mimic")


def get(name: str) -> Attack:
    return REGISTRY[name]
