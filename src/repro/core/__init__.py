"""Core contribution of the paper: VRMOM estimator + RCSL algorithm."""
from . import aggregators, attacks, estimator, rcsl, vrmom
from .estimator import Estimator
from .vrmom import mom, vrmom as vrmom_estimate, sigma_k_sq, sigma_mom_sq

__all__ = [
    "aggregators",
    "attacks",
    "estimator",
    "Estimator",
    "rcsl",
    "vrmom",
    "mom",
    "vrmom_estimate",
    "sigma_k_sq",
    "sigma_mom_sq",
]
