"""Core contribution of the paper: VRMOM estimator + RCSL algorithm."""
from . import aggregators, attacks, rcsl, vrmom
from .vrmom import mom, vrmom as vrmom_estimate, sigma_k_sq, sigma_mom_sq

__all__ = [
    "aggregators",
    "attacks",
    "rcsl",
    "vrmom",
    "mom",
    "vrmom_estimate",
    "sigma_k_sq",
    "sigma_mom_sq",
]
