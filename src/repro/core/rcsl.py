"""Robust CSL (RCSL) — Algorithm 1 of the paper.

One round (master H0 = shard 0):
  1. broadcast theta; every machine j computes g_j = (1/n) sum grad f(X_i, theta)
  2. Byzantine machines send arbitrary values instead
  3. master aggregates coordinate-wise with VRMOM (or any aggregator)
  4. master minimizes the CSL surrogate
        (1/n) sum_{i in H0} f(X_i, theta) - <g_0 - g_bar, theta>

``Problem`` abstracts the model: local gradients, the H0 per-sample
gradients (for the paper-faithful sigma_hat), the surrogate solve, and —
for the statistical-inference layer (``repro.infer``, DESIGN.md §9) —
the per-machine plug-in statistics ``local_hessian`` (the local loss
Hessian at theta) and ``local_moments`` (first/second moments of the
per-sample gradients, the inputs to the sandwich covariance).
Linear regression has the paper's closed form; logistic regression uses
Newton; ``GenericProblem`` uses autodiff + gradient descent.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attacks
from .estimator import Estimator
from .vrmom import vrmom as _vrmom


class Shards(NamedTuple):
    """Data evenly split over m+1 machines. X: [m+1, n, p], Y: [m+1, n]."""

    X: jnp.ndarray
    Y: jnp.ndarray


# ---------------------------------------------------------------------------
# Problems
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinearRegressionProblem:
    """f(x, theta) = (y - x^T theta)^2  (paper Section 4.2)."""

    ridge: float = 0.0

    def local_grad(self, theta, X, Y):
        resid = X @ theta - Y  # [n]
        return 2.0 * (X.T @ resid) / X.shape[0]

    def per_sample_grads(self, theta, X, Y):
        resid = X @ theta - Y
        return 2.0 * X * resid[:, None]  # [n, p]

    def local_hessian(self, theta, X, Y):
        """Local loss Hessian 2 X^T X / n (the ridge is a solver aid,
        not part of the inferential target, so it is excluded)."""
        return 2.0 * (X.T @ X) / X.shape[0]

    def local_moments(self, theta, X, Y):
        """(mean, second moment) of the per-sample gradients, closed
        form: g_i = 2 x_i r_i, so E_n[g g^T] = 4 X^T diag(r^2) X / n."""
        n = X.shape[0]
        resid = X @ theta - Y
        g1 = 2.0 * (X.T @ resid) / n
        g2 = 4.0 * jnp.einsum("np,n,nq->pq", X, resid * resid, X) / n
        return g1, g2

    def init_theta(self, X, Y):
        n, p = X.shape
        A = X.T @ X / n + self.ridge * jnp.eye(p)
        return jnp.linalg.solve(A, X.T @ Y / n)

    def master_solve(self, theta, X, Y, linear_term):
        """argmin (1/n) sum (y - x^T th)^2 - <linear_term, th> (closed form)."""
        n, p = X.shape
        A = 2.0 * (X.T @ X) / n + self.ridge * jnp.eye(p)
        b = 2.0 * (X.T @ Y) / n + linear_term
        return jnp.linalg.solve(A, b)


@dataclasses.dataclass(frozen=True)
class LogisticRegressionProblem:
    """f(x, theta) = log(1 + exp(x^T th)) - y x^T th; Newton master solve."""

    newton_iters: int = 25
    ridge: float = 1e-8

    def local_grad(self, theta, X, Y):
        mu = jax.nn.sigmoid(X @ theta)
        return X.T @ (mu - Y) / X.shape[0]

    def per_sample_grads(self, theta, X, Y):
        mu = jax.nn.sigmoid(X @ theta)
        return X * (mu - Y)[:, None]

    def local_hessian(self, theta, X, Y):
        mu = jax.nn.sigmoid(X @ theta)
        w = mu * (1.0 - mu)
        return (X.T * w) @ X / X.shape[0]

    def local_moments(self, theta, X, Y):
        n = X.shape[0]
        d = jax.nn.sigmoid(X @ theta) - Y
        g1 = X.T @ d / n
        g2 = jnp.einsum("np,n,nq->pq", X, d * d, X) / n
        return g1, g2

    def init_theta(self, X, Y):
        p = X.shape[1]
        return self._newton(jnp.zeros(p), X, Y, jnp.zeros(p))

    def master_solve(self, theta, X, Y, linear_term):
        return self._newton(theta, X, Y, linear_term)

    def _newton(self, theta, X, Y, linear_term):
        n = X.shape[0]

        def body(theta, _):
            mu = jax.nn.sigmoid(X @ theta)
            g = X.T @ (mu - Y) / n - linear_term
            w = mu * (1.0 - mu)
            H = (X.T * w) @ X / n + self.ridge * jnp.eye(X.shape[1])
            return theta - jnp.linalg.solve(H, g), None

        theta, _ = jax.lax.scan(body, theta, None, length=self.newton_iters)
        return theta


@dataclasses.dataclass(frozen=True)
class GenericProblem:
    """Any differentiable per-sample loss ``loss_fn(theta, x, y)``."""

    loss_fn: Callable
    master_steps: int = 200
    lr: float = 0.1

    def _mean_loss(self, theta, X, Y):
        return jnp.mean(jax.vmap(self.loss_fn, in_axes=(None, 0, 0))(theta, X, Y))

    def local_grad(self, theta, X, Y):
        return jax.grad(self._mean_loss)(theta, X, Y)

    def per_sample_grads(self, theta, X, Y):
        return jax.vmap(jax.grad(self.loss_fn), in_axes=(None, 0, 0))(theta, X, Y)

    def local_hessian(self, theta, X, Y):
        return jax.hessian(self._mean_loss)(theta, X, Y)

    def local_moments(self, theta, X, Y):
        g = self.per_sample_grads(theta, X, Y)  # [n, p]
        return jnp.mean(g, axis=0), g.T @ g / g.shape[0]

    def init_theta(self, X, Y):
        theta = jnp.zeros(X.shape[1])
        return self.master_solve(theta, X, Y, jnp.zeros_like(theta))

    def master_solve(self, theta, X, Y, linear_term):
        def body(theta, _):
            g = jax.grad(self._mean_loss)(theta, X, Y) - linear_term
            return theta - self.lr * g, None

        theta, _ = jax.lax.scan(body, theta, None, length=self.master_steps)
        return theta


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------


def aggregate_gradients(
    grads,
    aggregator: str = "vrmom",
    K: int = 10,
    scale: str = "master",
    per_sample_grads_master=None,
    **agg_kwargs,
):
    """Aggregate stacked per-machine gradients ``[m+1, p]`` (eq. 18/20).

    VRMOM with a non-default scale — the paper-faithful ``'master'``
    (H0 per-sample std) or an explicit array — is handled here: those
    scale modes need inputs only the statistical path has. Everything
    else goes through the unified ``Estimator`` layer on its jnp backend
    (the [m+1, p] stacks of the statistical experiments are too small
    for the fused kernels to matter, and whole-vector estimators stay
    usable on full vectors).
    """
    est = Estimator.coerce(aggregator, backend="jnp", **agg_kwargs)
    if isinstance(aggregator, str) and est.method in ("vrmom",
                                                      "vrmom_adaptive"):
        est = est._replace(K=K)  # bind the legacy K arg; an explicit
        # Estimator keeps its own K verbatim
    non_mad = not (isinstance(scale, str) and scale == "mad")
    if est.method == "vrmom" and non_mad:
        master = (per_sample_grads_master
                  if isinstance(scale, str) and scale == "master" else None)
        return _vrmom(grads, K=est.K, scale=scale, master_samples=master)
    return est.apply(grads, axis=0)


def rcsl(
    problem,
    shards: Shards,
    key: jax.Array,
    alpha: float = 0.0,
    attack: str = "none",
    aggregator: str = "vrmom",
    K: int = 10,
    scale: str = "master",
    rounds: int = 10,
    tol: Optional[float] = 1e-4,
    theta0: Optional[jnp.ndarray] = None,
    labelflip: bool = False,
    reduce_backend: str = "direct",
    consensus=None,
    fault_plan=None,
    **agg_kwargs,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run Algorithm 1. Returns (theta_T, theta_trajectory [rounds+1, p]).

    ``labelflip=True`` implements the paper's logistic attack mode: the
    Byzantine machines compute *honest* gradients on data whose labels
    were flipped (Y -> 1 - Y) rather than sending arbitrary vectors.
    ``tol``: adaptive stopping |th_t - th_{t-1}|^2/|th_{t-1}|^2 <= tol;
    after triggering, the trajectory repeats the converged iterate (the
    computation stays fixed-shape for jit).

    ``reduce_backend="consensus"`` replaces the master's one-shot
    aggregation (step 3) with the peer-to-peer consensus iteration
    (DESIGN.md §13): every machine f-trims and averages what it hears
    until eps-agreement, under an optional ``dist.faults.FaultPlan``
    (dropout/crashes/stragglers) — Byzantine rows keep re-broadcasting
    their corrupted payload every round. The master-scale VRMOM
    special case does not apply there (consensus rounds run the §7
    Estimator with its own mad scale); ``consensus`` is a
    ``dist.consensus.ConsensusConfig`` (default derives ``f`` from
    ``alpha``).
    """
    X, Y = shards.X, shards.Y
    m1 = X.shape[0]
    mask = attacks.byzantine_mask(m1, alpha)
    attack_fn = attacks.get(attack)

    if reduce_backend not in ("direct", "consensus"):
        raise ValueError(f"unknown reduce_backend {reduce_backend!r}; "
                         "known: ('direct', 'consensus')")
    if reduce_backend == "consensus":
        from ..dist.consensus import ConsensusConfig, consensus_aggregate

        est_c = Estimator.coerce(aggregator, backend="jnp", **agg_kwargs)
        if isinstance(aggregator, str) and est_c.method == "vrmom":
            est_c = est_c._replace(K=K)
        if consensus is None:
            n_byz = int(alpha * (m1 - 1))
            consensus = ConsensusConfig(f=max(n_byz, 1) if m1 > 5 else 0)
        consensus.validate(m1)

    if theta0 is None:
        theta0 = problem.init_theta(X[0], Y[0])

    Y_byz = (1.0 - Y) if labelflip else Y

    def one_round(carry, key_t):
        theta, done = carry
        grads_h = jax.vmap(problem.local_grad, in_axes=(None, 0, 0))(theta, X, Y)
        if labelflip:
            grads_b = jax.vmap(problem.local_grad, in_axes=(None, 0, 0))(
                theta, X, Y_byz
            )
            grads = jnp.where(mask[:, None], grads_b, grads_h)
        else:
            grads = attack_fn(key_t, grads_h, mask)
        if reduce_backend == "consensus":
            # fold_in (not split) keeps the attack stream bit-identical
            # to the direct backend for the same outer key.
            gbar, _caux = consensus_aggregate(
                grads.astype(jnp.float32), est_c, config=consensus,
                plan=fault_plan, key=jax.random.fold_in(key_t, 7),
                pin_mask=mask)
            gbar = gbar.astype(grads.dtype)
        else:
            psg = (problem.per_sample_grads(theta, X[0], Y[0])
                   if scale == "master" else None)
            gbar = aggregate_gradients(
                grads, aggregator=aggregator, K=K, scale=scale,
                per_sample_grads_master=psg, **agg_kwargs,
            )
        g0 = grads[0]
        theta_new = problem.master_solve(theta, X[0], Y[0], g0 - gbar)
        if tol is not None:
            e = jnp.sum((theta_new - theta) ** 2) / jnp.maximum(
                jnp.sum(theta**2), 1e-30
            )
            done_new = jnp.logical_or(done, e <= tol)
            theta_new = jnp.where(done, theta, theta_new)
            return (theta_new, done_new), theta_new
        return (theta_new, done), theta_new

    keys = jax.random.split(key, rounds)
    (theta_T, _), traj = jax.lax.scan(one_round, (theta0, jnp.asarray(False)), keys)
    traj = jnp.concatenate([theta0[None], traj], axis=0)
    return theta_T, traj


def make_shards(key, N_per_machine: int, m_workers: int, p: int, theta_star,
                model: str = "linear", mu_x: float = 0.0,
                toeplitz_rho: float = 0.5, noise_std: float = 1.0) -> Shards:
    """Generate the paper's simulation data (Section 4.2), already sharded.

    Covariates ~ N(mu_x, Sigma) with Toeplitz Sigma_ij = rho^|i-j|.
    """
    m1 = m_workers + 1
    kx, ke = jax.random.split(key)
    idx = jnp.arange(p)
    Sigma = toeplitz_rho ** jnp.abs(idx[:, None] - idx[None, :])
    L = jnp.linalg.cholesky(Sigma)
    Z = jax.random.normal(kx, (m1, N_per_machine, p))
    X = Z @ L.T + mu_x
    eta = X @ theta_star
    if model == "linear":
        Y = eta + noise_std * jax.random.normal(ke, (m1, N_per_machine))
    elif model == "logistic":
        U = jax.random.uniform(ke, (m1, N_per_machine))
        Y = (U < jax.nn.sigmoid(eta)).astype(jnp.float32)
    else:
        raise ValueError(model)
    return Shards(X=X, Y=Y)


def paper_theta_star(p: int) -> jnp.ndarray:
    """theta* = p^{-1/2} (1, (p-2)/(p-1), (p-3)/(p-1), ..., 0) (Section 4)."""
    if p == 1:
        return jnp.ones((1,))
    ks = jnp.arange(p)
    vals = jnp.concatenate([jnp.ones((1,)), (p - 1 - ks[1:]) / (p - 1)])
    return vals / jnp.sqrt(p)
