"""Variance-Reduced Median-of-Means (VRMOM) estimator.

Implements eq. (2) [MOM], eq. (7) [VRMOM] and eq. (9) [asymptotic
variance sigma_K^2] of Tu, Liu, Mao & Chen (2021), "Variance Reduced
Median-of-Means Estimator for Byzantine-Robust Distributed Inference".

All estimators act coordinate-wise along a designated *worker* axis of an
array of per-machine means ``xbar`` with shape ``[m+1, ...]``; up to an
``alpha < 1/2`` fraction of rows may be arbitrary (Byzantine).

Scale handling
--------------
The paper writes the correction in terms of ``sigma_hat / sqrt(n)`` where
``sigma_hat`` is the per-sample std estimated on the trusted master
machine H0.  Internally we work with the *mean-level* noise scale
``s = sigma / sqrt(n)`` (the std of one machine's mean), which is what
actually enters eq. (7).  Three ways to supply it:

* ``scale='mad'`` (default): robust cross-worker estimate
  ``s = MAD_j(xbar_j) / ndtri(0.75)`` — itself median-based, hence
  Byzantine-robust; consistent for sigma/sqrt(n) under the same CLT
  argument as the paper's. TPU-adaptation documented in DESIGN.md §2.
* ``scale='master'`` with ``master_samples``: the paper-faithful H0
  sample std divided by sqrt(n).
* ``scale=<array>``: explicit ``s`` (broadcastable to ``xbar`` minus the
  worker axis).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.scipy.special import ndtr, ndtri

__all__ = [
    "mom",
    "vrmom",
    "mad_scale",
    "master_scale",
    "deltas",
    "psi",
    "psi_sum",
    "sigma_k_sq",
    "sigma_mom_sq",
    "vrmom_correction_bound",
]

_MAD_CONST = 0.6744897501960817  # ndtri(0.75)


def psi(x):
    """Standard normal pdf."""
    return jnp.exp(-0.5 * jnp.square(x)) / jnp.sqrt(2.0 * jnp.pi)


def _ndtri_np(p):
    """Inverse normal CDF, pure numpy (host-side; never traced)."""
    import numpy as np

    try:
        from scipy.special import ndtri as _sndtri

        return _sndtri(p)
    except Exception:  # pragma: no cover - scipy-free fallback (Acklam)
        p = np.asarray(p, dtype=np.float64)
        a = [-3.969683028665376e01, 2.209460984245205e02, -2.759285104469687e02,
             1.383577518672690e02, -3.066479806614716e01, 2.506628277459239e00]
        b = [-5.447609879822406e01, 1.615858368580409e02, -1.556989798598866e02,
             6.680131188771972e01, -1.328068155288572e01]
        c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e00,
             -2.549732539343734e00, 4.374664141464968e00, 2.938163982698783e00]
        d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e00,
             3.754408661907416e00]
        plow, phigh = 0.02425, 1 - 0.02425
        x = np.empty_like(p)
        lo = p < plow
        hi = p > phigh
        mid = ~(lo | hi)
        q = np.sqrt(-2 * np.log(p[lo]))
        x[lo] = (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / (
            (((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
        q = p[mid] - 0.5
        r = q * q
        x[mid] = (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * q / (
            ((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)
        q = np.sqrt(-2 * np.log(1 - p[hi]))
        x[hi] = -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / (
            (((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
        return x


@functools.lru_cache(maxsize=64)
def _deltas_cached(K: int):
    import numpy as np

    taus = np.arange(1, K + 1, dtype=np.float64) / (K + 1)
    return np.asarray(_ndtri_np(taus), dtype=np.float64)


def deltas(K: int, dtype=jnp.float32):
    """Delta_k = ndtri(k/(K+1)) for k = 1..K."""
    return jnp.asarray(_deltas_cached(K), dtype=dtype)


@functools.lru_cache(maxsize=64)
def psi_sum(K: int) -> float:
    """sum_k psi(Delta_k) as a python float."""
    import numpy as np

    d = _deltas_cached(K)
    return float(np.sum(np.exp(-0.5 * d * d) / np.sqrt(2.0 * np.pi)))


def mom(xbar, axis: int = 0):
    """Median-of-means, eq. (2): coordinate-wise median over ``axis``."""
    return jnp.median(xbar, axis=axis)


def mad_scale(xbar, axis: int = 0, center=None):
    """Robust scale of the per-machine means: MAD / ndtri(3/4)."""
    if center is None:
        center = jnp.median(xbar, axis=axis, keepdims=True)
    else:
        center = jnp.expand_dims(center, axis)
    return jnp.median(jnp.abs(xbar - center), axis=axis) / _MAD_CONST


def master_scale(master_samples, axis: int = 0):
    """Paper-faithful scale: H0 per-sample std / sqrt(n).

    ``master_samples``: raw per-sample values on the trusted master, shape
    ``[n, ...]`` along ``axis``. Returns ``sigma_hat / sqrt(n)``.
    """
    n = master_samples.shape[axis]
    sigma = jnp.std(master_samples, axis=axis)
    return sigma / jnp.sqrt(jnp.asarray(n, master_samples.dtype))


def _resolve_scale(xbar, axis, scale, master_samples, mu_hat):
    if isinstance(scale, str):
        if scale == "mad":
            return mad_scale(xbar, axis=axis, center=mu_hat)
        if scale == "master":
            if master_samples is None:
                raise ValueError("scale='master' requires master_samples")
            return master_scale(master_samples)
        raise ValueError(f"unknown scale {scale!r}")
    return jnp.asarray(scale)


def vrmom(
    xbar,
    K: int = 10,
    axis: int = 0,
    scale="mad",
    master_samples=None,
    eps: float = 1e-12,
):
    """VRMOM estimator, eq. (7) of the paper.

    Args:
      xbar: per-machine means, worker axis ``axis`` of size m+1.
      K: number of quantile levels (tau_k = k/(K+1)).
      scale: 'mad' | 'master' | explicit mean-level scale ``s``.
      master_samples: raw H0 samples, required iff scale='master'.
      eps: guards division when the scale is ~0 (constant inputs).

    Returns the estimate with the worker axis removed.
    """
    xbar = jnp.asarray(xbar)
    m1 = xbar.shape[axis]
    mu_hat = jnp.median(xbar, axis=axis)
    s = _resolve_scale(xbar, axis, scale, master_samples, mu_hat)
    s = jnp.broadcast_to(s, mu_hat.shape)

    d = deltas(K, dtype=jnp.promote_types(xbar.dtype, jnp.float32))
    # z_j = (xbar_j - mu_hat) / s ; summand_j = sum_k 1(z_j <= Delta_k) - K/2
    z = (xbar - jnp.expand_dims(mu_hat, axis)) / jnp.expand_dims(
        jnp.maximum(s, eps), axis
    )
    # Count via comparisons (exact; avoids ceil edge cases at Phi in {0,1}).
    z_e = jnp.expand_dims(z, -1)  # [..., 1]
    counts = jnp.sum(z_e <= d, axis=-1).astype(z.dtype)  # [m+1, ...]
    summand = counts - K / 2.0
    total = jnp.sum(summand, axis=axis)
    corr = s * total / (m1 * psi_sum(K))
    out = mu_hat - corr
    # If the scale is degenerate (all-equal inputs) the correction is 0/0;
    # fall back to the median.
    return jnp.where(s <= eps, mu_hat, out).astype(xbar.dtype)


def vrmom_correction_bound(K: int) -> float:
    """Deterministic bound: |vrmom - mom| <= s * (K/2) / sum_k psi(Delta_k).

    Follows from |sum_k 1(.) - K/2| <= K/2 per machine (Remark 2)."""
    return (K / 2.0) / psi_sum(K)


# ---------------------------------------------------------------------------
# Theory: asymptotic variances (eq. 9 and Minsker 2019 for MOM)
# ---------------------------------------------------------------------------

def sigma_k_sq(K: int) -> float:
    """sigma_K^2 / sigma^2 from eq. (9). -> pi/3 as K -> inf; K=1 gives pi/2."""
    import numpy as np

    taus = np.arange(1, K + 1, dtype=np.float64) / (K + 1)
    t1 = taus[:, None]
    t2 = taus[None, :]
    num = np.sum(np.minimum(t1, t2) * (1.0 - np.maximum(t1, t2)))
    den = float(psi_sum(K)) ** 2
    return float(num / den)


def sigma_mom_sq() -> float:
    """MOM asymptotic variance factor: pi/2 (Minsker 2019)."""
    return math.pi / 2.0


# ---------------------------------------------------------------------------
# Theorem 4 / Proposition 1: multivariate asymptotic covariance matrices
# ---------------------------------------------------------------------------

def _phi2_cdf_grid(a, b, rho, n_grid: int = 2001, lim: float = 8.0):
    """P(Z1 <= a, Z2 <= b) for standard bivariate normal with corr rho,
    via P = int_{-lim}^{a} phi(z) Phi((b - rho z)/sqrt(1-rho^2)) dz
    (host-side numpy quadrature; exact enough for the tests)."""
    import numpy as np

    if abs(rho) >= 1.0 - 1e-12:
        if rho > 0:  # P(Z <= min(a, b))
            return 0.5 * (1 + math.erf(min(a, b) / math.sqrt(2.0)))
        # rho = -1: P(Z <= a, -Z <= b) = P(-b <= Z <= a)
        return max(0.0, 0.5 * (math.erf(a / math.sqrt(2))
                               + math.erf(b / math.sqrt(2))))
    z = np.linspace(-lim, min(a, lim), n_grid)
    phi = np.exp(-0.5 * z * z) / np.sqrt(2 * np.pi)
    arg = (b - rho * z) / math.sqrt(1.0 - rho * rho)
    Phi = 0.5 * (1.0 + np.vectorize(math.erf)(arg / np.sqrt(2.0)))
    return float(np.trapezoid(phi * Phi, z))


def vrmom_asymptotic_cov(Sigma, K: int):
    """The matrix C of Theorem 4 (eq. 13/14): sqrt(N)(mu_bar - mu) -> N(0, C).

    Sigma: [p, p] covariance of X. Host-side numpy (theory utility).
    """
    import numpy as np

    Sigma = np.asarray(Sigma, dtype=np.float64)
    p = Sigma.shape[0]
    sd = np.sqrt(np.diag(Sigma))
    corr = Sigma / np.outer(sd, sd)
    d = _deltas_cached(K)
    taus = np.arange(1, K + 1, dtype=np.float64) / (K + 1)
    den = psi_sum(K) ** 2
    C = np.zeros((p, p))
    for l1 in range(p):
        for l2 in range(l1, p):
            rho = float(np.clip(corr[l1, l2], -1.0, 1.0))
            acc = 0.0
            for k1 in range(K):
                for k2 in range(K):
                    t12 = _phi2_cdf_grid(d[k1], d[k2], rho)
                    acc += t12 - taus[k1] * taus[k2]
            C[l1, l2] = C[l2, l1] = acc / den * sd[l1] * sd[l2]
    return C


def mom_asymptotic_cov(Sigma):
    """C_MOM of Proposition 1 (eq. 17)."""
    import numpy as np

    Sigma = np.asarray(Sigma, dtype=np.float64)
    p = Sigma.shape[0]
    sd = np.sqrt(np.diag(Sigma))
    corr = Sigma / np.outer(sd, sd)
    C = np.zeros((p, p))
    for l1 in range(p):
        for l2 in range(l1, p):
            rho = float(np.clip(corr[l1, l2], -1.0, 1.0))
            t = _phi2_cdf_grid(0.0, 0.0, rho)
            C[l1, l2] = C[l2, l1] = (2 * np.pi * t - np.pi / 2) \
                * sd[l1] * sd[l2]
    return C
