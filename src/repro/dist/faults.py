"""jit-pure fault injection for the consensus backend (DESIGN.md §13).

A :class:`FaultPlan` is a hashable static spec — like ``Estimator``
(§7) it keys the jit trace cache, so two plans with different fault
structure compile separately while the *randomness* (which message is
dropped this round) stays inside the trace, drawn from a PRNG key
folded with the round index. Everything here returns fixed-shape
arrays, composing with ``vmap``/``jit``/``shard_map``.

Worker-index convention (``n`` = total consensus peers):

* **crashed** workers occupy the *first* ``n_crashed`` indices — they
  stop sending permanently from round ``crash_round`` on;
* **stragglers** occupy the next ``n_stragglers`` indices — they keep
  sending, but serve the value they held ``stale_rounds`` rounds ago;
* **Byzantine** workers (``core.attacks.byzantine_mask``) occupy the
  *last* rows.

The three populations are therefore disjoint by construction as long
as ``n_crashed + n_stragglers + n_byzantine <= n``, which lets a test
compose a ``FaultPlan`` with any registered attack payload without the
fault model accidentally silencing the adversary (a crashed Byzantine
worker is just a crashed worker).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["FaultPlan"]


class FaultPlan(NamedTuple):
    """Static description of the failures injected into a consensus run.

    ``dropout``      — iid per-round, per-(receiver, sender) message
                       loss probability (self-delivery never drops).
    ``n_crashed``    — workers that crash permanently...
    ``crash_round``  — ...at the start of this round (0 = from the
                       first exchange; fail-stop, not fail-recover).
    ``n_stragglers`` — workers whose sends are stale:
    ``stale_rounds`` — they serve the value held ``k`` rounds earlier
                       (their round-0 value for the first ``k`` rounds).
    """
    dropout: float = 0.0
    n_crashed: int = 0
    crash_round: int = 0
    n_stragglers: int = 0
    stale_rounds: int = 1

    # -- static structure ---------------------------------------------------
    @property
    def trivial(self) -> bool:
        """True when the plan injects nothing — the fault-free fast
        path (pure ``Estimator`` rounds, no masking) is exact."""
        return (self.dropout == 0.0 and self.n_crashed == 0
                and self.n_stragglers == 0)

    def validate(self, n: int) -> "FaultPlan":
        if not 0.0 <= float(self.dropout) < 1.0:
            raise ValueError(f"dropout must be in [0, 1), got {self.dropout}")
        if self.n_crashed < 0 or self.n_stragglers < 0:
            raise ValueError("n_crashed / n_stragglers must be >= 0")
        if self.n_crashed + self.n_stragglers > n:
            raise ValueError(
                f"FaultPlan places {self.n_crashed} crashed + "
                f"{self.n_stragglers} straggler workers on only {n} peers")
        if self.n_stragglers and self.stale_rounds < 1:
            raise ValueError("stale_rounds must be >= 1 when stragglers > 0")
        return self

    # -- static masks (host ints in, jnp arrays out) ------------------------
    def crashed_mask(self, n: int) -> jnp.ndarray:
        """[n] bool — workers that *will* crash (first ``n_crashed``)."""
        return jnp.arange(n) < self.n_crashed

    def straggler_mask(self, n: int) -> jnp.ndarray:
        """[n] bool — stale senders (indices after the crashed block)."""
        idx = jnp.arange(n)
        return ((idx >= self.n_crashed)
                & (idx < self.n_crashed + self.n_stragglers))

    # -- per-round traced state --------------------------------------------
    def crashed_at(self, n: int, p) -> jnp.ndarray:
        """[n] bool — workers already crashed in round ``p`` (traced)."""
        return self.crashed_mask(n) & (jnp.asarray(p) >= self.crash_round)

    def recv_matrix(self, key, n: int, p) -> jnp.ndarray:
        """[n, n] bool — ``recv[i, j]``: receiver ``i`` got sender
        ``j``'s round-``p`` message.

        The diagonal is always True (a worker always has its own
        value); columns of crashed senders go False once ``p`` reaches
        ``crash_round``; every other edge drops iid with probability
        ``dropout`` under ``fold_in(key, p)``. Deterministic in
        ``(key, p)``, so the emulation and the shard_map backend — which
        evaluate it redundantly on every shard — see the same matrix.
        """
        eye = jnp.eye(n, dtype=bool)
        recv = jnp.ones((n, n), dtype=bool)
        if self.dropout > 0.0:
            up = jax.random.uniform(jax.random.fold_in(key, p), (n, n))
            recv = eye | (up >= self.dropout)
        if self.n_crashed:
            recv = recv & ~self.crashed_at(n, p)[None, :]
        return recv


# FaultPlan is a static jit argument: reject unhashable fields at
# construction, same guard (and same caveat about _replace) as the §7
# Estimator spec.
_orig_new = FaultPlan.__new__


def _checked_new(cls, *args, **kwargs):
    from ..lint.hashguard import check_hashable_fields
    plan = _orig_new(cls, *args, **kwargs)
    check_hashable_fields(plan)
    return plan


FaultPlan.__new__ = _checked_new
