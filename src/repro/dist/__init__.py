"""Distributed substrate: mesh context, sharding rules, robust reduction.

Three modules (DESIGN.md §3):

* ``ctx`` — ambient mesh context (``mesh_context``/``constrain``/
  ``axis_size``) that model layers query lazily, plus the
  robust-backward state used by ``robust_reduce.robust_dot``.
* ``sharding`` — PartitionSpec rules: ``param_specs`` (divisibility-aware
  TP/FSDP placement), ``batch_axes_for``, ``stacked_grad_specs``,
  ``opt_state_specs``, ``to_named``.
* ``robust_reduce`` — Byzantine-robust gradient aggregation: the
  shard_map all_to_all Robust-Reduce-Scatter (``aggregate_stacked_rrs``),
  its jit-native twin (``aggregate_stacked_auto``), and the in-backward
  path (``robust_backward`` + ``robust_dot``).
"""
from __future__ import annotations

from . import ctx, robust_reduce, sharding  # noqa: F401

__all__ = ["ctx", "robust_reduce", "sharding"]
