"""Distributed substrate: mesh context, sharding rules, robust reduction.

Five modules (DESIGN.md §3, §13):

* ``ctx`` — ambient mesh context (``mesh_context``/``constrain``/
  ``axis_size``) that model layers query lazily, plus the
  robust-backward state used by ``robust_reduce.robust_dot``.
* ``sharding`` — PartitionSpec rules: ``param_specs`` (divisibility-aware
  TP/FSDP placement), ``batch_axes_for``, ``stacked_grad_specs``,
  ``opt_state_specs``, ``to_named``.
* ``robust_reduce`` — Byzantine-robust gradient aggregation: the
  shard_map all_to_all Robust-Reduce-Scatter (``aggregate_stacked_rrs``),
  its jit-native twin (``aggregate_stacked_auto``), and the in-backward
  path (``robust_backward`` + ``robust_dot``).
* ``consensus`` — the coordinator-free alternative (DESIGN.md §13):
  iterative trimmed-mean/midpoint approximate consensus on the same
  stacked wire (``aggregate_stacked_consensus`` + the mesh-free
  ``consensus_aggregate`` emulation), tolerating ``f`` Byzantine peers
  with ``n > 5f`` plus message loss.
* ``faults`` — jit-pure fault injection (``FaultPlan``): per-round
  message dropout, permanent crashes, stale stragglers — composable
  with the ``core/attacks`` Byzantine payloads.
"""
from __future__ import annotations

from . import consensus, ctx, faults, robust_reduce, sharding  # noqa: F401

__all__ = ["consensus", "ctx", "faults", "robust_reduce", "sharding"]
