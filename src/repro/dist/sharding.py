"""PartitionSpec rules for params, batches, caches and optimizer state.

All rules are *divisibility-aware*: an axis is only placed on a dim when
the axis size divides it and the dim is at least twice the axis size
(so degenerate placements like sharding a 4-wide conv-tap dim across 4
FSDP shards are skipped). A rule that does not fit degrades to
replication, never to an error — the same config must lower on the
2x16x16 production mesh and a 4x2 host test mesh.

Naming conventions (DESIGN.md §3): ``model`` is the tensor-parallel
axis, ``data`` the FSDP/batch axis, ``pod`` an optional outer batch
axis; (``pod``, ``data``) together form the *worker axes* of the robust
aggregation.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "param_specs",
    "batch_axes_for",
    "batch_specs",
    "cache_specs",
    "stacked_grad_specs",
    "opt_state_specs",
    "to_named",
]

_WORKER_AXIS_ORDER = ("pod", "data")


def _axis(mesh, name: str) -> int:
    return int(mesh.shape[name]) if name in mesh.axis_names else 1


def _fits(dim: int, ax: int) -> bool:
    """Is placing an axis of size ``ax`` on a dim of size ``dim`` sane?"""
    return ax > 1 and dim % ax == 0 and dim >= 2 * ax


def _key_str(k) -> str:
    return str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", ""))))


def param_specs(shapes, mesh):
    """Tree of PartitionSpecs for a params tree of ShapeDtypeStructs.

    Placement rules (model = TP axis, data = FSDP axis):

    * embed ``[V, D]`` — model on the vocab dim when divisible, else
      moved to ``D``, else dropped (whisper's 51865 vocab does not
      divide a 16-way model axis); data on whichever of the two dims
      remains divisible.
    * attention ``wq/wk/wv [L, D, H, dh]`` — model on the *head* dim
      only when the head count divides it; odd head counts (36, 24) are
      REPLICATED, never moved to head_dim — sharding ``dh`` splits every
      score contraction and forces a per-layer all-reduce of the
      attention scores. data on ``D``.
    * ``wo [L, H, dh, D]`` — model on heads, data on ``D``.
    * MLP / MoE ``w_gate/w_up`` — model on the ``d_ff`` (last) dim,
      ``w_down`` — model on ``d_ff`` (second-to-last); data on the
      ``d_model`` dim. Expert and layer-stack dims stay replicated
      ("tensor-parallel experts", DESIGN.md §4).
    * generic 2D+ fallback — model on the last dim, data on the
      second-to-last, each only when it fits.
    """
    tp = _axis(mesh, "model")
    dp = _axis(mesh, "data")

    def spec_for(path, leaf):
        shape = tuple(leaf.shape)
        nd = len(shape)
        if nd <= 1:
            return P(*([None] * nd))
        name = _key_str(path[-1]) if path else ""

        if name in ("embed", "lm_head"):
            # [V, D] or [D, V]; prefer model on the vocab dim.
            vdim = 0 if name == "embed" else 1
            entries = [None, None]
            if _fits(shape[vdim], tp):
                entries[vdim] = "model"
            elif _fits(shape[1 - vdim], tp):
                entries[1 - vdim] = "model"
            other = entries.index(None) if None in entries else None
            if other is not None and _fits(shape[other], dp):
                entries[other] = "data"
            return P(*entries)

        if name in ("wq", "wk", "wv", "wo") and nd in (3, 4):
            # stacked [L, D, H, dh] / [L, H, dh, D]; unstacked drops L.
            off = nd - 3
            h_dim = off + (0 if name == "wo" else 1)
            d_dim = off + (2 if name == "wo" else 0)
            entries = [None] * nd
            if _fits(shape[h_dim], tp):
                entries[h_dim] = "model"
            if _fits(shape[d_dim], dp):
                entries[d_dim] = "data"
            return P(*entries)

        if name in ("w_gate", "w_up", "w_down"):
            # [..., D, F] (gate/up) or [..., F, D] (down): model on F.
            f_dim = nd - 1 if name != "w_down" else nd - 2
            d_dim = nd - 2 if name != "w_down" else nd - 1
            entries = [None] * nd
            if _fits(shape[f_dim], tp):
                entries[f_dim] = "model"
            if _fits(shape[d_dim], dp):
                entries[d_dim] = "data"
            return P(*entries)

        if name == "router":
            # [..., D, E]: experts rarely divide the model axis; FSDP on D.
            entries = [None] * nd
            if _fits(shape[-1], tp):
                entries[-1] = "model"
            if _fits(shape[-2], dp):
                entries[-2] = "data"
            return P(*entries)

        # generic: model on last dim, data on second-to-last.
        entries = [None] * nd
        if _fits(shape[-1], tp):
            entries[-1] = "model"
        if _fits(shape[-2], dp):
            entries[-2] = "data"
        return P(*entries)

    return jax.tree_util.tree_map_with_path(spec_for, shapes)


def batch_axes_for(mesh, global_batch: int):
    """Mesh axes to shard the batch dim over, or None when nothing fits.

    Tries the full worker-axis tuple first, then progressively drops
    outer axes: (pod, data) -> (data,) -> None.
    """
    names = [a for a in _WORKER_AXIS_ORDER if a in mesh.axis_names]
    for i in range(len(names)):
        axes = tuple(names[i:])
        total = 1
        for a in axes:
            total *= int(mesh.shape[a])
        if total > 0 and global_batch % total == 0:
            return axes
    return None


def batch_specs(specs, batch_axes):
    """P-tree for a batch tree: dim 0 on ``batch_axes``, rest replicated."""
    def one(leaf):
        nd = len(leaf.shape)
        if batch_axes is None or nd == 0:
            return P(*([None] * nd))
        return P(batch_axes, *([None] * (nd - 1)))

    return jax.tree.map(one, specs)


def cache_specs(cfg, cache_shapes, mesh, batch_axes, global_batch=None):
    """P-tree for decode caches: batch dim on ``batch_axes``, the widest
    post-batch dim on ``model`` when it fits, layer-stack dims replicated.

    The batch dim is located by size (``global_batch``); without it the
    cache is conservatively left batch-replicated.
    """
    tp = _axis(mesh, "model")

    def one(leaf):
        shape = tuple(leaf.shape)
        nd = len(shape)
        entries = [None] * nd
        b_dim = None
        if global_batch is not None and batch_axes is not None and nd >= 2:
            # Batch sits after the layer-stack dims: dim 1 for plain
            # stacked caches [L, B, ...], dim 2 for hybrid group stacks
            # [G, every, B, ...]. Size-matching cannot fully
            # disambiguate (a stack dim may equal the batch size);
            # preference order 1 > 2 > 0 resolves the common layouts,
            # and a wrong pick still yields a valid (divisible) if
            # suboptimal layout.
            cands = [i for i, d in enumerate(shape) if d == global_batch]
            for pref in (1, 2, 0):
                if pref in cands:
                    b_dim = pref
                    break
            if b_dim is None and cands:
                b_dim = cands[0]
            if b_dim is not None:
                entries[b_dim] = batch_axes
        if b_dim is not None and nd > b_dim + 1:
            tail = range(b_dim + 1, nd)
            cand = max(tail, key=lambda i: shape[i])
            if _fits(shape[cand], tp):
                entries[cand] = "model"
        return P(*entries)

    return jax.tree.map(one, cache_shapes)


def stacked_grad_specs(params_specs, worker_axes, mesh, shapes=None):
    """Specs for per-worker stacked grads ``[n_workers, *param_shape]``.

    Dim 0 goes on the worker axes; the param spec shifts right by one
    with any mention of a worker axis removed (a mesh axis cannot
    appear twice in one spec — FSDP placement on ``data`` is subsumed
    by the worker-stacking dim). ``shapes`` is accepted so callers can
    pass the matching param shapes for future divisibility re-checks.
    """
    wa = tuple(worker_axes)

    def one(spec):
        cleaned = []
        for e in spec:
            if e is None:
                cleaned.append(None)
            elif isinstance(e, tuple):
                kept = tuple(a for a in e if a not in wa)
                cleaned.append(kept if kept else None)
            else:
                cleaned.append(None if e in wa else e)
        return P(wa if wa else None, *cleaned)

    return jax.tree.map(one, params_specs,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(opt_state_shapes, params, params_specs):
    """Specs for optimizer state mirroring the params tree.

    Handles: 'm'/'v' trees shaped like params; adafactor's nested
    {'vr','vc'} / {'v'} dicts (vr = spec[:-1], vc = spec minus dim -2).
    """
    flat_params, ptree = jax.tree.flatten(params)
    flat_specs = ptree.flatten_up_to(params_specs)
    shape2spec = {}
    for p, s in zip(flat_params, flat_specs):
        shape2spec.setdefault(tuple(p.shape), s)

    def leaf_spec(path, leaf):
        names = [_key_str(k) for k in path]
        shp = tuple(leaf.shape)
        if shp in shape2spec:
            return shape2spec[shp]
        name = names[-1] if names else ""
        # factored adafactor leaves: find the parent param by prefix match
        if name in ("vr", "vc"):
            for pshape, s in shape2spec.items():
                entries = list(s) + [None] * (len(pshape) - len(s))
                if name == "vr" and pshape[:-1] == shp:
                    return P(*entries[:-1])
                if name == "vc" and pshape[:-2] + pshape[-1:] == shp:
                    return P(*entries[:-2], entries[-1])
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(leaf_spec, opt_state_shapes)


def to_named(mesh, specs):
    """P-tree -> NamedSharding-tree on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
