"""Decentralized approximate consensus over the mesh worker axes.

The coordinator-free alternative to Robust-Reduce-Scatter (DESIGN.md
§13): instead of one all_to_all + one all_gather with a designated
owner per coordinate chunk, every worker is a peer. Each round a
worker broadcasts its current value vector, f-trims whatever arrives,
and moves to the trimmed aggregate; after a *static* number of rounds

    ``p_end = ceil(log(eps / K) / log(1/2))``

(the JACM86 phase bound for convergence factor 1/2 per round, with
``K = init_range`` the assumed bound on the initial spread) every
honest worker holds the same value to within ``eps``. Validity
requires ``n > 5f`` — refused at trace time, mirroring
``robust_dot``'s divisibility refusal — and each round proceeds on any
``n - f`` received values (the quorum), so the iteration tolerates
message dropout, stragglers serving stale values, and permanent
crashes injected by a :class:`repro.dist.faults.FaultPlan`.

Two executions of the same round semantics:

* ``consensus_iterate`` / ``consensus_aggregate`` — mesh-free jit
  emulation on a local ``[n, C]`` stack (every receiver's view is
  materialized, ``O(n^2 C)`` on the fault path). The numerical oracle,
  and the backend for `infer/coverage` cells and small-n callers.
* ``aggregate_stacked_consensus`` — the shard_map backend: same
  stacked-gradient wire and sharding specs as ``aggregate_stacked_rrs``
  (leaves ``[n_workers, *param]``, dim 0 on the worker axes, model
  axis partitioning coordinates), one ``all_gather`` per round inside
  a ``lax.fori_loop`` with the static ``p_end`` bound.

Fault-free with ``trim="mean"``, a round *is* one §7 ``Estimator``
aggregate of the gathered stack — every peer computes the identical
value, the iteration is idempotent from round 1 on, and the output
equals ``aggregate_stacked_auto``/``_rrs`` exactly. Under faults the
per-receiver reception masks differ, so rounds run the masked f-trim
(``sort`` + windowed mean or midpoint) instead; receivers below
quorum hold their previous value, and quorum loss is *reported* (aux
flag + ``dist.quorum`` gauge), never a NaN.

Adversary model: attacks from ``core/attacks`` corrupt the initial
stack (static adversary); passing the Byzantine mask as ``pin_mask``
upgrades them to *persistent* senders that re-broadcast their corrupt
payload every round — the regime the ``n > 5f`` bound is for.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.estimator import Estimator
from ..obs.trace import named_span
from .faults import FaultPlan

__all__ = [
    "ConsensusConfig",
    "ConsensusAux",
    "consensus_iterate",
    "consensus_aggregate",
    "aggregate_stacked_consensus",
]

EstimatorLike = Union[str, Estimator]

# Missing-message sentinel: sorts after any real payload but stays a
# normal float (no inf arithmetic anywhere near the trim windows), and
# is far above every attack payload in the zoo (|omniscient| ~ 1e10).
_MISSING = jnp.float32(3.0e38)

TRIM_MODES = ("mean", "midpoint")


class ConsensusConfig(NamedTuple):
    """Static spec of the consensus iteration (hashable, keys jit).

    ``f``          — Byzantine peers tolerated; drives both the
                     per-round trim width and the ``n - f`` quorum.
    ``eps``        — target agreement diameter.
    ``init_range`` — ``K``: assumed bound on the initial honest spread
                     (enters only through the log in ``p_end``).
    ``trim``       — per-round update: ``"mean"`` (trimmed mean; the
                     §7 Estimator fault-free) or ``"midpoint"``
                     (JACM86 trimmed midpoint).
    ``max_rounds`` — optional hard cap on ``p_end``.
    """
    f: int = 1
    eps: float = 1e-4
    init_range: float = 64.0
    trim: str = "mean"
    max_rounds: Optional[int] = None

    def validate(self, n: int) -> "ConsensusConfig":
        """Trace-time validity: approximate consensus under Byzantine
        peers *and* message loss requires ``n > 5f`` (JACM86). ``n``
        and ``f`` are static, so — like ``robust_dot``'s divisibility
        guard — an invalid deployment refuses to trace rather than
        silently losing the convergence guarantee."""
        if self.trim not in TRIM_MODES:
            raise ValueError(
                f"unknown trim mode {self.trim!r}; known: {TRIM_MODES}")
        if self.f < 0:
            raise ValueError(f"f must be >= 0, got {self.f}")
        if n <= 5 * self.f:
            raise ValueError(
                f"consensus validity needs n > 5f: n={n} peers cannot "
                f"tolerate f={self.f} Byzantine faults (need n >= "
                f"{5 * self.f + 1} or f <= {(n - 1) // 5})")
        if not 0.0 < self.eps < self.init_range:
            raise ValueError(
                f"need 0 < eps < init_range, got eps={self.eps}, "
                f"init_range={self.init_range}")
        return self

    def phases(self, plan: Optional[FaultPlan] = None) -> int:
        """Static round bound ``p_end = ceil(log(eps/K)/log(1/2))``.

        Receivers below quorum hold their value instead of updating,
        so with message dropout the bound is doubled — at the 10%
        dropout / n=8 operating point the per-round update probability
        stays well above 1/2, leaving margin to spare. Staleness adds
        its window on top. ``max_rounds`` caps the result.
        """
        p = max(1, math.ceil(math.log(self.eps / self.init_range)
                             / math.log(0.5)))
        if plan is not None:
            if plan.dropout > 0.0:
                p *= 2
            if plan.n_stragglers:
                p += int(plan.stale_rounds)
        if self.max_rounds is not None:
            p = min(p, int(self.max_rounds))
        return p


class ConsensusAux(NamedTuple):
    """Fixed-shape jit aux outputs of one consensus aggregate.

    Drained host-side into the §11 metrics (``consensus.rounds``
    histogram, ``dist.messages_dropped`` counter, ``dist.quorum``
    gauge); every field is a scalar array so the pytree rides any jit
    boundary unchanged.
    """
    rounds_run: jax.Array        # [] int32 — static phase bound executed
    rounds_to_eps: jax.Array     # [] int32 — first round with honest
    #                                 spread <= eps (rounds_run if never)
    spread: jax.Array            # [] f32  — final honest-alive spread
    quorum: jax.Array            # [] f32  — fraction of (round, alive
    #                                 receiver) slots meeting n-f quorum
    quorum_lost: jax.Array       # [] bool — no alive receiver met quorum
    #                                 in the final round
    messages_dropped: jax.Array  # [] int32 — alive->alive messages lost


# ---------------------------------------------------------------------------
# round primitives (shared by the emulation and the shard_map backend)
# ---------------------------------------------------------------------------

def _masked_trim(vals, recv, f: int, trim: str):
    """f-trimmed aggregate of the received subset of ``vals``.

    ``vals``: [n, C]; ``recv``: [n] bool. Missing rows are replaced by
    the ``_MISSING`` sentinel so they sort to the top; the trim window
    ``[f, n_recv - f)`` then only ever touches real payloads. Returns
    [C]; always finite (empty windows fall back to 0 — callers gate on
    quorum before trusting the value).
    """
    n = vals.shape[0]
    vm = jnp.where(recv[:, None], vals, _MISSING)
    srt = jnp.sort(vm, axis=0)
    n_recv = jnp.sum(recv.astype(jnp.int32))
    idx = jnp.arange(n, dtype=jnp.int32)[:, None]
    if trim == "midpoint":
        lo_i = jnp.clip(f, 0, jnp.maximum(n_recv - 1, 0))
        hi_i = jnp.clip(n_recv - 1 - f, lo_i, n - 1)
        lo = jnp.sum(jnp.where(idx == lo_i, srt, 0.0), axis=0)
        hi = jnp.sum(jnp.where(idx == hi_i, srt, 0.0), axis=0)
        return jnp.where(n_recv > 0, 0.5 * (lo + hi), 0.0)
    keep = (idx >= f) & (idx < n_recv - f)
    denom = jnp.maximum(n_recv - 2 * f, 1).astype(jnp.float32)
    return jnp.sum(jnp.where(keep, srt, 0.0), axis=0) / denom


def _spread(vals, mask):
    """[] f32 — max over coordinates of (max - min) over ``mask`` rows
    of ``vals`` [n, C]; 0 when fewer than two rows are selected."""
    m = mask[:, None]
    hi = jnp.max(jnp.where(m, vals, -_MISSING), axis=0)
    lo = jnp.min(jnp.where(m, vals, _MISSING), axis=0)
    sp = jnp.max(hi - lo)
    return jnp.where(jnp.sum(mask) >= 2, sp, 0.0)


def _rounds_to_eps(spreads, final_spread, eps, p_end: int):
    """First round index whose *entering* honest spread is <= eps
    (spreads[p] is measured on the values entering round p, so index p
    means "converged after p rounds"); ``p_end`` if only the final
    values — or nothing — made it."""
    conv = jnp.concatenate([spreads, final_spread[None]]) <= eps
    return jnp.where(jnp.any(conv), jnp.argmax(conv),
                     p_end).astype(jnp.int32)


class _RoundView(NamedTuple):
    """Per-round fault state, computed identically on every shard from
    the (replicated) plan + key: reception matrix, liveness, quorum."""
    recv: jax.Array      # [n, n] bool — recv[i, j]: i received j
    alive: jax.Array     # [n] bool
    q_ok: jax.Array      # [n] bool — receiver met the n-f quorum
    dropped: jax.Array   # [] int32 — alive->alive messages lost


def _round_view(plan: FaultPlan, key, n: int, p, quorum: int) -> _RoundView:
    recv = plan.recv_matrix(key, n, p)
    alive = ~plan.crashed_at(n, p)
    q_ok = jnp.sum(recv, axis=1) >= quorum
    expected = (alive[:, None] & alive[None, :]) & ~jnp.eye(n, dtype=bool)
    dropped = jnp.sum(expected & ~recv).astype(jnp.int32)
    return _RoundView(recv, alive, q_ok, dropped)


def _prep(stack_n: int, est: EstimatorLike, config, plan, key):
    """Shared argument normalization + trace-time validation."""
    est = Estimator.coerce(est).require_coordinatewise(
        "consensus rounds (dist.consensus)")
    config = (config if config is not None else ConsensusConfig())
    if not isinstance(config, ConsensusConfig):
        raise TypeError(f"expected ConsensusConfig, got {type(config)!r}")
    config.validate(stack_n)
    plan = (plan if plan is not None else FaultPlan()).validate(stack_n)
    if key is None:
        key = jax.random.PRNGKey(0)
    return est, config, plan, key


# ---------------------------------------------------------------------------
# mesh-free emulation
# ---------------------------------------------------------------------------

def consensus_iterate(stack, est: EstimatorLike = "vrmom", *,
                      config: Optional[ConsensusConfig] = None,
                      plan: Optional[FaultPlan] = None,
                      key=None, pin_mask=None
                      ) -> Tuple[jax.Array, ConsensusAux]:
    """Run the full consensus iteration on a local ``[n, C]`` stack.

    Returns ``(finals, aux)`` where ``finals`` [n, C] holds every
    peer's value after ``p_end`` rounds. ``pin_mask`` [n] bool marks
    persistent Byzantine senders (they re-broadcast their initial —
    already attack-corrupted — row every round and never update).
    Jit/vmap-pure; the fault path materializes every receiver's view
    (``O(n^2 C)`` work per round).
    """
    n, _C = stack.shape
    est, config, plan, key = _prep(n, est, config, plan, key)
    f, trim, eps = config.f, config.trim, config.eps
    p_end = config.phases(plan)
    quorum = n - f
    v0 = stack.astype(jnp.float32)
    strag = plan.straggler_mask(n)
    k = int(plan.stale_rounds) if plan.n_stragglers else 0
    pin = None if pin_mask is None else jnp.asarray(pin_mask)
    hist0 = (jnp.broadcast_to(v0, (k,) + v0.shape) if k
             else jnp.zeros((0,) + v0.shape, jnp.float32))

    def body(p, carry):
        v, hist, spreads, dropped, q_sum, _last_q = carry
        sent = jnp.where(strag[:, None], hist[k - 1], v) if k else v
        if pin is not None:
            sent = jnp.where(pin[:, None], v0, sent)
        rv = _round_view(plan, key, n, p, quorum)
        honest = rv.alive if pin is None else rv.alive & ~pin
        if plan.trivial and trim == "mean":
            new = jnp.broadcast_to(est.apply(sent, axis=0)[None], v.shape)
        else:
            new = jax.vmap(
                lambda r: _masked_trim(sent, r, f, trim))(rv.recv)
        upd = (rv.q_ok & rv.alive)[:, None]
        v_new = jnp.where(upd, new, v)
        hist_new = (jnp.concatenate([v_new[None], hist[:k - 1]]) if k > 1
                    else (v_new[None] if k else hist))
        spreads = spreads.at[p].set(_spread(sent, honest))
        dropped = dropped + rv.dropped
        n_alive = jnp.maximum(jnp.sum(rv.alive), 1)
        q_sum = q_sum + jnp.sum(rv.q_ok & rv.alive) / n_alive
        return v_new, hist_new, spreads, dropped, q_sum, jnp.any(
            rv.q_ok & rv.alive)

    init = (v0, hist0, jnp.zeros((p_end,), jnp.float32),
            jnp.int32(0), jnp.float32(0.0), jnp.bool_(True))
    with named_span("consensus.round_loop"):
        finals, _, spreads, dropped, q_sum, last_q = jax.lax.fori_loop(
            0, p_end, body, init)
    if pin is not None:
        finals = jnp.where(pin[:, None], v0, finals)
    alive_end = ~plan.crashed_at(n, p_end)
    honest_end = alive_end if pin is None else alive_end & ~pin
    aux = ConsensusAux(
        rounds_run=jnp.int32(p_end),
        rounds_to_eps=_rounds_to_eps(
            spreads, _spread(finals, honest_end), eps, p_end),
        spread=_spread(finals, honest_end),
        quorum=q_sum / jnp.float32(p_end),
        quorum_lost=~last_q,
        messages_dropped=dropped,
    )
    return finals, aux


def consensus_aggregate(stack, est: EstimatorLike = "vrmom", *,
                        config: Optional[ConsensusConfig] = None,
                        plan: Optional[FaultPlan] = None,
                        key=None, pin_mask=None
                        ) -> Tuple[jax.Array, ConsensusAux]:
    """``[n, C] -> ([C], ConsensusAux)``: iterate, then decide.

    The decision is the f-trimmed aggregate over the still-alive
    peers' final values — robust to up to ``f`` persistent Byzantine
    rows, finite (never NaN) even below quorum. Fault-free with
    ``trim="mean"`` every final row is the identical Estimator output,
    and that value is returned exactly.
    """
    n, _C = stack.shape
    est_c, config_c, plan_c, key = _prep(n, est, config, plan, key)
    finals, aux = consensus_iterate(stack, est_c, config=config_c,
                                    plan=plan_c, key=key, pin_mask=pin_mask)
    if plan_c.trivial and config_c.trim == "mean" and pin_mask is None:
        return finals[0], aux
    alive_end = ~plan_c.crashed_at(n, config_c.phases(plan_c))
    out = _masked_trim(finals, alive_end, config_c.f, config_c.trim)
    return out, aux


# ---------------------------------------------------------------------------
# shard_map backend — the RRS-wire drop-in
# ---------------------------------------------------------------------------

def aggregate_stacked_consensus(grads, mesh, worker_axes,
                                est: EstimatorLike = "vrmom", *,
                                config: Optional[ConsensusConfig] = None,
                                plan: Optional[FaultPlan] = None,
                                key=None, pin_mask=None, specs=None):
    """Peer-to-peer consensus aggregate of a stacked-gradient pytree.

    Drop-in for ``aggregate_stacked_rrs``: same wire (leaves
    ``[n_workers, *param]``, dim 0 sharded over ``worker_axes``,
    ``specs`` overriding the canonical layout), same output pytree with
    the worker dim removed — plus a :class:`ConsensusAux`, always:
    returns ``(pytree, aux)``. No worker owns any coordinate; each
    round is one ``all_gather`` of every peer's wire vector followed by
    the per-receiver f-trim, ``p_end`` rounds under a static
    ``fori_loop``. Non-worker mesh axes partition coordinates exactly
    as in RRS (each model shard converges on its own slice; aux spread
    is ``pmax``-ed across them).

    The leading dim of every leaf must equal the worker count — unlike
    RRS there is no meaningful reshape fallback for a mismatched stack.
    """
    from .robust_reduce import (_canonical_stacked_spec, _n_workers,
                                aggregate_stacked_auto)

    worker_axes = tuple(worker_axes)
    nw = _n_workers(mesh, worker_axes)
    if nw <= 1:
        # A one-peer mesh has nothing to disagree about: emulate with
        # f=0 (f>0 could never satisfy n > 5f at n=1).
        cfg1 = config if config is not None else ConsensusConfig()
        if isinstance(cfg1, ConsensusConfig) and cfg1.f != 0:
            cfg1 = cfg1._replace(f=0)
        return aggregate_stacked_auto(
            grads, est, reduce_backend="consensus", consensus=cfg1,
            plan=plan, key=key, pin_mask=pin_mask)
    est, config, plan, key = _prep(nw, est, config, plan, key)
    if jnp.issubdtype(jnp.asarray(key).dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    f, trim, eps = config.f, config.trim, config.eps
    p_end = config.phases(plan)
    quorum = nw - f
    k = int(plan.stale_rounds) if plan.n_stragglers else 0
    has_pin = pin_mask is not None

    leaves, treedef = jax.tree.flatten(grads)
    for l in leaves:
        if l.shape[0] != nw:
            raise ValueError(
                f"consensus wire: leaf {l.shape} must lead with the "
                f"{nw} workers of axes {worker_axes}")
    if specs is not None:
        in_specs = jax.tree.leaves(specs,
                                   is_leaf=lambda x: isinstance(x, P))
    else:
        in_specs = [_canonical_stacked_spec(l.shape, mesh, worker_axes)
                    for l in leaves]
    leaves = [jax.lax.with_sharding_constraint(l, NamedSharding(mesh, s))
              for l, s in zip(leaves, in_specs)]
    out_specs = [P(*s[1:]) for s in in_specs]
    other_axes = tuple(a for a in mesh.axis_names if a not in worker_axes)
    pin_arg = (jnp.zeros((nw,), bool) if pin_mask is None
               else jnp.asarray(pin_mask))
    aux_specs = ConsensusAux(*([P()] * len(ConsensusAux._fields)))

    def local_consensus(key_arg, pin, *blocks):
        w_loc = blocks[0].shape[0]
        if w_loc != 1:
            raise ValueError(
                f"consensus wire: specs leave {w_loc} worker rows on one "
                f"shard; the worker dim must be fully sharded over "
                f"{worker_axes}")
        flat = jnp.concatenate(
            [b.reshape(w_loc, -1).astype(jnp.float32) for b in blocks],
            axis=1)
        rank = 0
        for a in worker_axes:
            rank = rank * int(mesh.shape[a]) + jax.lax.axis_index(a)
        strag = plan.straggler_mask(nw)
        v0 = flat[0]

        def exchange(sent):
            return jax.lax.all_gather(sent, worker_axes, axis=0,
                                      tiled=False).reshape(nw, -1)

        def body(p, carry):
            v, hist, spreads, dropped, q_sum, _last_q = carry
            sent = jnp.where(strag[rank], hist[k - 1], v) if k else v
            if has_pin:
                sent = jnp.where(pin[rank], v0, sent)
            allv = exchange(sent)
            rv = _round_view(plan, key_arg, nw, p, quorum)
            honest = rv.alive & ~pin if has_pin else rv.alive
            if plan.trivial and trim == "mean":
                new = est.apply(allv, axis=0)
            else:
                new = _masked_trim(allv, rv.recv[rank], f, trim)
            upd = rv.q_ok[rank] & rv.alive[rank]
            v_new = jnp.where(upd, new, v)
            hist_new = (jnp.concatenate([v_new[None], hist[:k - 1]])
                        if k > 1 else (v_new[None] if k else hist))
            spreads = spreads.at[p].set(_spread(allv, honest))
            dropped = dropped + rv.dropped
            n_alive = jnp.maximum(jnp.sum(rv.alive), 1)
            q_sum = q_sum + jnp.sum(rv.q_ok & rv.alive) / n_alive
            return (v_new, hist_new, spreads, dropped, q_sum,
                    jnp.any(rv.q_ok & rv.alive))

        hist0 = (jnp.broadcast_to(v0, (k,) + v0.shape) if k
                 else jnp.zeros((0,) + v0.shape, jnp.float32))
        init = (v0, hist0, jnp.zeros((p_end,), jnp.float32),
                jnp.int32(0), jnp.float32(0.0), jnp.bool_(True))
        with named_span("consensus.round_loop"):
            v_fin, _, spreads, dropped, q_sum, last_q = jax.lax.fori_loop(
                0, p_end, body, init)

        if has_pin:
            v_fin = jnp.where(pin[rank], v0, v_fin)
        finals = exchange(v_fin)
        alive_end = ~plan.crashed_at(nw, p_end)
        honest_end = alive_end & ~pin if has_pin else alive_end
        if plan.trivial and trim == "mean" and not has_pin:
            wire = finals[0]
        else:
            wire = _masked_trim(finals, alive_end, f, trim)
        final_spread = _spread(finals, honest_end)
        if other_axes:  # model shards each watched their own slice
            spreads = jax.lax.pmax(spreads, other_axes)
            final_spread = jax.lax.pmax(final_spread, other_axes)
        aux = ConsensusAux(
            rounds_run=jnp.int32(p_end),
            rounds_to_eps=_rounds_to_eps(spreads, final_spread, eps, p_end),
            spread=final_spread,
            quorum=q_sum / jnp.float32(p_end),
            quorum_lost=~last_q,
            messages_dropped=dropped,
        )
        outs, off = [], 0
        for b in blocks:
            size = b.size // w_loc
            outs.append(wire[off:off + size]
                        .reshape(b.shape[1:]).astype(b.dtype))
            off += size
        return tuple(outs) + (aux,)

    results = shard_map(
        local_consensus, mesh=mesh,
        in_specs=(P(None), P(None)) + tuple(in_specs),
        out_specs=tuple(out_specs) + (aux_specs,),
        check_rep=False)(key, pin_arg, *leaves)
    agg_leaves, aux = results[:-1], results[-1]
    return jax.tree.unflatten(treedef, agg_leaves), aux
