"""Ambient distributed context.

Model layers must not take a mesh argument (they are called from vmap /
scan bodies where threading one through would contaminate every
signature), so the active mesh lives in a trace-time context stack that
``train/step.py`` and the serve steps push via ``mesh_context``. Layers
then ask two questions lazily:

* ``axis_size(name)`` — how many shards along a mesh axis (1 when no
  mesh is active or the axis does not exist), e.g. to pad attention
  heads up to the tensor-parallel degree.
* ``constrain(x, *entries)`` — a best-effort
  ``with_sharding_constraint``: axis names absent from the mesh or not
  dividing the dimension degrade to UNCONSTRAINED instead of erroring,
  and the whole call is a no-op outside tracing or without a mesh, so
  single-device eager tests run the exact same layer code.

The stack is trace-time state only (pushed while jit traces the step
function); it is not part of the compiled computation.

This module also holds the robust-backward state consumed by
``robust_reduce.robust_dot`` (DESIGN.md §2): while a
``robust_backward(mesh, worker_axes, ...)`` context is active, the
layers' ``_dot`` routes matmuls through the custom-VJP robust dot.
"""
from __future__ import annotations

import contextlib
from typing import NamedTuple, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "U",
    "mesh_context",
    "current_mesh",
    "axis_size",
    "constrain",
    "RobustBackwardState",
    "push_robust_backward",
    "pop_robust_backward",
    "robust_backward_state",
]

U = P.UNCONSTRAINED  # per-dim "let GSPMD decide" sentinel

_MESH_STACK: list = []


@contextlib.contextmanager
def mesh_context(mesh):
    """Make ``mesh`` the ambient mesh for constrain()/axis_size()."""
    _MESH_STACK.append(mesh)
    try:
        yield mesh
    finally:
        _MESH_STACK.pop()


def current_mesh():
    """The innermost active mesh, or None."""
    return _MESH_STACK[-1] if _MESH_STACK else None


def axis_size(name: str) -> int:
    """Size of mesh axis ``name`` in the ambient mesh (1 if absent)."""
    mesh = current_mesh()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return int(mesh.shape[name])


def _clean_entry(mesh, entry, dim: int):
    """Validate one PartitionSpec entry against the mesh and dim size.

    Unknown axes and non-dividing products degrade to UNCONSTRAINED —
    callers state intent for the *production* mesh and smaller test
    meshes must not error.
    """
    if entry is U or entry is None:
        return entry
    names = entry if isinstance(entry, tuple) else (entry,)
    kept = tuple(a for a in names
                 if a in mesh.axis_names and int(mesh.shape[a]) > 1)
    if not kept:
        return U
    total = 1
    for a in kept:
        total *= int(mesh.shape[a])
    if dim % total:
        return U
    return kept if len(kept) > 1 else kept[0]


def constrain(x, *entries):
    """Best-effort with_sharding_constraint under the ambient mesh.

    ``entries`` has one element per dim of ``x``: an axis name, a tuple
    of axis names, None (replicate), or ``U`` (unconstrained). No-op
    when no mesh is active or when called eagerly (hints only matter to
    GSPMD during tracing).
    """
    mesh = current_mesh()
    if mesh is None or not isinstance(x, jax.core.Tracer):
        return x
    cleaned = [_clean_entry(mesh, e, d) for e, d in zip(entries, x.shape)]
    if all(e is U for e in cleaned):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*cleaned)))


# ---------------------------------------------------------------------------
# Robust-backward state (consumed by robust_reduce.robust_dot)
# ---------------------------------------------------------------------------

class RobustBackwardState(NamedTuple):
    """Active IB-RRS config: mesh + worker axes + the Estimator spec
    (``core.estimator.Estimator``) that ``robust_dot`` aggregates with."""

    mesh: object
    worker_axes: Tuple[str, ...]
    estimator: object


_RB_STACK: list = []


def push_robust_backward(state: RobustBackwardState) -> None:
    _RB_STACK.append(state)


def pop_robust_backward() -> RobustBackwardState:
    return _RB_STACK.pop()


def robust_backward_state() -> Optional[RobustBackwardState]:
    """Innermost active robust-backward config, or None."""
    return _RB_STACK[-1] if _RB_STACK else None
