"""Byzantine-robust gradient reduction over the mesh worker axes.

Three execution strategies for the same semantics — coordinate-wise
robust aggregation (VRMOM eq. 7 / MOM / trimmed mean / mean) of
per-worker gradients stacked on a leading worker dim:

* ``aggregate_stacked_rrs`` — Robust-Reduce-Scatter (RRS, DESIGN.md §3):
  a shard_map over the mesh in which every worker shard (1) flattens and
  concatenates all of its local gradient leaves into one f32 wire
  vector, (2) all_to_all's it over the worker axes so each worker
  receives all workers' values for its 1/W slice of coordinates,
  (3) runs the coordinate-wise robust estimator on its slice, and
  (4) all_gathers the aggregated slices back. Constant number of
  collective rounds (one all_to_all + one all_gather) regardless of
  worker count — the paper's one-round communication property mapped
  onto a device mesh.
* ``aggregate_stacked_auto`` — jit-native twin: the same estimator
  applied per-leaf under GSPMD, no explicit collectives. Must match RRS
  to 2e-5 (tested); used as numerical oracle and on meshes where the
  worker axes are trivial.
* ``robust_backward`` + ``robust_dot`` — in-backward RRS (IB-RRS,
  DESIGN.md §2): a custom-VJP matmul whose weight gradient is the
  stacked robust aggregate of per-worker dW, computed inside the
  backward pass so the full per-worker gradient pytree is never
  materialized (the stacked modes' f32 copy alone would blow HBM on
  llama3-405b).

Which estimator runs, and on which backend, is a single
``core.estimator.Estimator`` spec (DESIGN.md §7) — every function here
takes one (or a method name, coerced) instead of loose method/K/flag
arguments. Whole-vector estimators (geometric median, Krum) are rejected
at trace time: the RRS wire format hands each worker a coordinate
*shard*, which only coordinate-wise estimators can aggregate correctly.

Non-worker mesh axes (``model``) partition the *coordinates*: the
estimators are coordinate-wise, so every tensor-parallel shard robustly
reduces its own slice with no cross-model communication.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Union

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.estimator import Estimator
from ..obs.trace import named_span
from . import ctx as CTX

__all__ = [
    "aggregate",
    "aggregate_stacked_rrs",
    "aggregate_stacked_auto",
    "aggregate_stacked_adaptive",
    "aggregate_symmetric_stacked",
    "robust_backward",
    "robust_dot",
    "robust_dot_enabled",
]

EstimatorLike = Union[str, Estimator]


def _n_workers(mesh, worker_axes) -> int:
    n = 1
    for a in worker_axes:
        n *= int(mesh.shape[a])
    return n


def _wire_estimator(est: EstimatorLike) -> Estimator:
    """Coerce + reject estimators that cannot ride the RRS wire format."""
    return Estimator.coerce(est).require_coordinatewise(
        "chunked/RRS aggregation (dist.robust_reduce)")


def _canonical_stacked_spec(shape, mesh, worker_axes):
    """Default layout for a stacked-grad leaf ``[W, ...]``: worker axes
    on dim 0, ``model`` on the last trailing dim it divides."""
    wa = tuple(worker_axes)
    entries = [None] * (len(shape) - 1)
    tp = int(mesh.shape["model"]) if "model" in mesh.axis_names else 1
    if tp > 1:
        for i in range(len(entries) - 1, -1, -1):
            if shape[i + 1] % tp == 0 and shape[i + 1] >= 2 * tp:
                entries[i] = "model"
                break
    return P(wa if wa else None, *entries)


def _with_tree_diag(grads, out):
    """Attach ``obs.diag`` statistics to an aggregated pytree.

    Computed jit-natively from the stacked tree against the aggregate
    (GSPMD reduces the per-leaf sums over whatever sharding the leaves
    carry — worker and model shards alike), so the same diag path
    serves every aggregation mode and never touches the RRS wire."""
    from ..obs import diag as OD

    with named_span("obs.tree_diagnose"):
        return out, OD.tree_diagnose(grads, out)


def aggregate_stacked_rrs(grads, mesh, worker_axes,
                          est: EstimatorLike = "vrmom", *, specs=None,
                          with_diag: bool = False):
    """Robust-Reduce-Scatter of a stacked-gradient pytree.

    ``grads``: pytree whose leaves are ``[n_workers, *param_shape]``,
    dim 0 sharded over ``worker_axes``. Returns the aggregated pytree
    with the worker dim removed; with ``with_diag`` a
    ``(pytree, obs.diag.AggDiagnostics)`` pair — fixed-shape suspicion
    scores / mask / alpha-hat / norms safe as jit aux outputs.

    Wire format (DESIGN.md §3): each worker shard's leaves are raveled
    to f32, concatenated in pytree-flatten order, and zero-padded to a
    multiple of ``n_workers``; coordinate chunk ``i`` of the wire vector
    is owned (aggregated) by worker-axis rank ``i``.
    """
    est = _wire_estimator(est)
    worker_axes = tuple(worker_axes)
    nw = _n_workers(mesh, worker_axes)
    if nw <= 1:
        return aggregate_stacked_auto(grads, est, with_diag=with_diag)

    leaves, treedef = jax.tree.flatten(grads)
    if specs is not None:
        in_specs = jax.tree.leaves(specs,
                                   is_leaf=lambda x: isinstance(x, P))
    else:
        in_specs = [_canonical_stacked_spec(l.shape, mesh, worker_axes)
                    for l in leaves]
    leaves = [jax.lax.with_sharding_constraint(l, NamedSharding(mesh, s))
              for l, s in zip(leaves, in_specs)]
    out_specs = [P(*s[1:]) for s in in_specs]

    def local_rrs(*blocks):
        w_loc = blocks[0].shape[0]
        flat = jnp.concatenate(
            [b.reshape(w_loc, -1).astype(jnp.float32) for b in blocks],
            axis=1)
        n = flat.shape[1]
        pad = (-n) % nw
        if pad:
            flat = jnp.pad(flat, ((0, 0), (0, pad)))
        # [W_loc, n_p] -> [W, n_p/W]: every worker rank now holds all
        # workers' values for its own coordinate slice.
        with named_span("rrs.all_to_all"):
            swapped = jax.lax.all_to_all(flat, worker_axes, split_axis=1,
                                         concat_axis=0, tiled=True)
        agg = est.apply(swapped, axis=0)
        full = jax.lax.all_gather(agg, worker_axes, axis=0, tiled=True)
        if pad:
            full = full[:n]
        outs, off = [], 0
        for b in blocks:
            size = b.size // w_loc
            outs.append(full[off:off + size]
                        .reshape(b.shape[1:]).astype(b.dtype))
            off += size
        return tuple(outs)

    agg_leaves = shard_map(
        local_rrs, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=tuple(out_specs), check_rep=False)(*leaves)
    out = jax.tree.unflatten(treedef, agg_leaves)
    if with_diag:
        return _with_tree_diag(jax.tree.unflatten(treedef, leaves), out)
    return out


def aggregate_stacked_auto(grads, est: EstimatorLike = "vrmom", *,
                           with_diag: bool = False,
                           reduce_backend: str = "direct",
                           consensus=None, plan=None, key=None,
                           pin_mask=None):
    """jit-native equivalent of ``aggregate_stacked_rrs``: the same
    coordinate-wise estimator per leaf, sharding left to GSPMD.

    ``reduce_backend="consensus"`` swaps the one-shot estimator for the
    mesh-free peer-to-peer consensus emulation (DESIGN.md §13): all
    leaves are raveled onto one ``[W, C]`` wire, iterated to
    eps-agreement under the optional ``FaultPlan``, and split back.
    The consensus path returns ``(pytree, ConsensusAux)`` (diag, when
    requested, appended last) — the direct path's signature is
    unchanged.

    Adaptive estimators (§14) take the same full ``[W, C]`` wire on the
    direct path — their census needs complete worker rows, so per-leaf
    aggregation would fragment the signal; coordinate-wise estimators
    keep the per-leaf path.
    """
    est = Estimator.coerce(est)
    if est.adaptive:
        est.require_stackable("full-stack aggregation (dist.robust_reduce)")
    else:
        est = _wire_estimator(est)
    if reduce_backend not in ("direct", "consensus"):
        raise ValueError(f"unknown reduce_backend {reduce_backend!r}; "
                         "known: ('direct', 'consensus')")
    if reduce_backend == "consensus":
        from .consensus import consensus_aggregate

        leaves, treedef = jax.tree.flatten(grads)
        W = leaves[0].shape[0]
        wire = jnp.concatenate(
            [l.reshape(W, -1).astype(jnp.float32) for l in leaves], axis=1)
        agg, aux = consensus_aggregate(wire, est, config=consensus,
                                       plan=plan, key=key,
                                       pin_mask=pin_mask)
        outs, off = [], 0
        for l in leaves:
            size = l.size // W
            outs.append(agg[off:off + size]
                        .reshape(l.shape[1:]).astype(l.dtype))
            off += size
        out = jax.tree.unflatten(treedef, outs)
        if with_diag:
            return out, aux, _with_tree_diag(grads, out)[1]
        return out, aux

    if est.adaptive:
        out = _wire_apply(grads, lambda wire: est.apply(wire, axis=0))
    else:
        def one(g):
            flat = g.reshape(g.shape[0], -1).astype(jnp.float32)
            out = est.apply(flat, axis=0)
            return out.reshape(g.shape[1:]).astype(g.dtype)

        out = jax.tree.map(one, grads)
    if with_diag:
        return _with_tree_diag(grads, out)
    return out


def _wire_apply(grads, agg_fn):
    """Ravel all leaves onto one f32 ``[W, C]`` wire, apply
    ``agg_fn(wire) -> [C]`` (or ``(out, *aux)``), split the aggregate
    back into the tree. Returns the tree, or ``(tree, *aux)``."""
    leaves, treedef = jax.tree.flatten(grads)
    W = leaves[0].shape[0]
    wire = jnp.concatenate(
        [l.reshape(W, -1).astype(jnp.float32) for l in leaves], axis=1)
    res = agg_fn(wire)
    agg, aux = (res, ()) if isinstance(res, jax.Array) else (res[0], res[1:])
    outs, off = [], 0
    for l in leaves:
        size = l.size // W
        outs.append(agg[off:off + size]
                    .reshape(l.shape[1:]).astype(l.dtype))
        off += size
    out = jax.tree.unflatten(treedef, outs)
    return out if not aux else (out,) + tuple(aux)


def aggregate_stacked_adaptive(grads, state, est: EstimatorLike, *,
                               with_diag: bool = False,
                               weights_beta: float = 0.5,
                               momentum: float = 0.0):
    """Stateful adaptive aggregate of a stacked-gradient pytree.

    All leaves ride one full ``[W, C]`` wire (the census needs complete
    worker rows) through ``Estimator.apply_adaptive``; the
    :class:`repro.core.adaptive.AdaptiveState` carry threads explicitly
    through the caller's step (RL211). Returns
    ``(pytree, new_state)``, diag appended last when requested.
    """
    est = Estimator.coerce(est).require_stackable(
        "full-stack adaptive aggregation (dist.robust_reduce)")
    if not est.adaptive:
        raise ValueError(
            f"aggregate_stacked_adaptive needs an adaptive estimator, "
            f"got {est.method!r}")
    out, new_state = _wire_apply(
        grads, lambda wire: est.apply_adaptive(
            wire, state, axis=0, weights_beta=weights_beta,
            momentum=momentum))
    if with_diag:
        return out, new_state, _with_tree_diag(grads, out)[1]
    return out, new_state


def aggregate_symmetric_stacked(mats, est: EstimatorLike = "vrmom"):
    """Robustly aggregate a stack of symmetric matrices ``[W, p, p]``.

    Used by the inference layer (DESIGN.md §9) for per-machine Hessian
    and gradient-second-moment stacks. Only the ``p(p+1)/2`` upper-
    triangle coordinates ride the wire — the redundant lower triangle
    would double the RRS payload for bit-identical columns — and the
    aggregated triangle is mirrored back, so the output is *exactly*
    symmetric (coordinate-wise aggregation of a symmetric stack is
    symmetric in exact arithmetic, but downstream ``linalg.solve``
    deserves the guarantee, not the accident).

    The triangle rows are complete per-worker records, so adaptive
    estimators (§14) are accepted alongside the coordinate-wise tier.
    """
    est = Estimator.coerce(est).require_stackable(
        "symmetric-stack aggregation (dist.robust_reduce)")
    W, p, q = mats.shape
    if p != q:
        raise ValueError(f"expected [W, p, p] symmetric stack, got {mats.shape}")
    iu = jnp.triu_indices(p)
    tri = mats[:, iu[0], iu[1]].astype(jnp.float32)   # [W, p(p+1)/2]
    agg = est.apply(tri, axis=0)
    out = jnp.zeros((p, p), jnp.float32).at[iu].set(agg)
    out = out + jnp.triu(out, 1).T
    return out.astype(mats.dtype)


def aggregate(grads, mesh, worker_axes, *, mode: str = "stacked-rrs",
              est: EstimatorLike = "vrmom", specs=None,
              with_diag: bool = False, consensus=None, plan=None,
              key=None, pin_mask=None):
    """Mode dispatcher used by ``train/step.py``.

    ``stacked-rrs`` — shard_map RRS; ``stacked-auto`` — jit-native;
    ``stacked-consensus`` — peer-to-peer approximate consensus on the
    same wire (DESIGN.md §13; returns ``(aggregate, ConsensusAux)``,
    diag appended last when requested, and takes the consensus-only
    ``consensus``/``plan``/``key``/``pin_mask`` arguments);
    ``mean`` — plain mean over the worker dim (the non-robust baseline).
    ``with_diag`` returns ``(aggregate, obs.diag.AggDiagnostics)`` for
    every mode (the mean baseline's suspicion scores are still defined —
    deviation from the mean — which is what makes its non-robustness
    visible in the telemetry).
    """
    if mode == "stacked-consensus":
        from .consensus import aggregate_stacked_consensus

        out, aux = aggregate_stacked_consensus(
            grads, mesh, worker_axes, est, config=consensus, plan=plan,
            key=key, pin_mask=pin_mask, specs=specs)
        if with_diag:
            return out, aux, _with_tree_diag(grads, out)[1]
        return out, aux
    if mode == "stacked-rrs":
        return aggregate_stacked_rrs(grads, mesh, worker_axes, est,
                                     specs=specs, with_diag=with_diag)
    if mode in ("stacked-auto", "auto"):
        return aggregate_stacked_auto(grads, est, with_diag=with_diag)
    if mode == "mean":
        out = jax.tree.map(
            lambda g: jnp.mean(g.astype(jnp.float32), axis=0).astype(g.dtype),
            grads)
        if with_diag:
            return _with_tree_diag(grads, out)
        return out
    raise ValueError(f"unknown aggregation mode {mode!r}")


# ---------------------------------------------------------------------------
# In-backward RRS (IB-RRS): robust_dot under a robust_backward context
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def robust_backward(mesh, worker_axes, est: EstimatorLike = "vrmom"):
    """Enable IB-RRS: while active, the layers' ``_dot`` routes 3-D
    matmuls through ``robust_dot`` so each weight gradient is robustly
    aggregated over the worker axes inside the backward pass."""
    CTX.push_robust_backward(
        CTX.RobustBackwardState(mesh, tuple(worker_axes),
                                _wire_estimator(est)))
    try:
        yield
    finally:
        CTX.pop_robust_backward()


def robust_dot_enabled() -> bool:
    return CTX.robust_backward_state() is not None


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _robust_dot(mesh, worker_axes, est, x, w):
    return jnp.einsum("bsd,df->bsf", x, w)


def _robust_dot_fwd(mesh, worker_axes, est, x, w):
    return _robust_dot(mesh, worker_axes, est, x, w), (x, w)


def _robust_dot_bwd(mesh, worker_axes, est, res, dy):
    x, w = res
    dx = jnp.einsum("bsf,df->bsd", dy, w).astype(x.dtype)
    nw = _n_workers(mesh, worker_axes)
    B = x.shape[0]
    if nw > 1 and B % nw:
        # Refusing beats silently degrading to a non-robust sum: batch
        # and worker count are static, so this fires at trace time.
        raise ValueError(
            f"robust_dot: batch dim {B} is not divisible by the "
            f"{nw} workers of axes {worker_axes}; dW cannot be "
            "grouped per worker")
    if nw <= 1:
        dw = jnp.einsum("bsd,bsf->df", x.astype(jnp.float32),
                        dy.astype(jnp.float32))
        return dx, dw.astype(w.dtype)
    # per-worker dW, then stacked robust aggregation (x's batch dim is
    # sharded over the worker axes, so the reshape keeps each worker's
    # slice resident and dws lands pre-stacked on its own shard).
    xw = x.reshape((nw, B // nw) + x.shape[1:])
    dyw = dy.reshape((nw, B // nw) + dy.shape[1:])
    dws = jnp.einsum("wbsd,wbsf->wdf", xw.astype(jnp.float32),
                     dyw.astype(jnp.float32))
    dws = jax.lax.with_sharding_constraint(
        dws, NamedSharding(
            mesh, _canonical_stacked_spec(dws.shape, mesh, worker_axes)))
    dw = aggregate_stacked_rrs(dws, mesh, worker_axes, est)
    return dx, dw.astype(w.dtype)


_robust_dot.defvjp(_robust_dot_fwd, _robust_dot_bwd)


def robust_dot(x, w):
    """``x @ w`` (x: [B, S, D], w: [D, F]) whose dW equals the stacked
    robust aggregate of per-worker dW. Requires an active
    ``robust_backward`` context; the worker count must divide B."""
    state = CTX.robust_backward_state()
    if state is None:
        return jnp.einsum("bsd,df->bsf", x, w)
    return _robust_dot(state.mesh, state.worker_axes, state.estimator, x, w)
