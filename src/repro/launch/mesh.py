"""Production mesh definitions.

A FUNCTION (not a module-level constant) so importing never touches jax
device state. Single pod: (data=16, model=16) = 256 chips. Multi-pod:
(pod=2, data=16, model=16) = 512 chips; the pod axis joins the worker
axis of the robust aggregation and shards the batch.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 4, model: int = 2, pod: int = 1):
    """Small mesh for CPU multi-device tests (host platform devices)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
