"""Launchers: mesh, dryrun (import sets 512 host devices!), sweep,
report, train. NOTE: do not import .dryrun from a process that needs
real device topology — it pins XLA_FLAGS at import time by design."""
from . import mesh
