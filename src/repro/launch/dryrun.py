import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)
# ^ MUST run before any other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes with ShapeDtypeStruct stand-ins (no allocation), print
memory_analysis / cost_analysis, and extract collective bytes from the
HLO for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
      --shape train_4k [--multi-pod] [--mode stacked-rrs] [--json out.json]
"""
import argparse
import json
import re
import sys

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get as get_arch, input_specs
from repro.dist import sharding as S
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.train.step import make_serve_steps, make_train_step

# v5e hardware constants for the roofline (system brief)
PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

_COLLECTIVE_RE = re.compile(
    r"^\s*\S+ = (\S+?) (all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)", re.M)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}


def _bytes_of_shape(stype: str) -> int:
    """Sum byte size over a (possibly tuple) HLO shape string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(stype):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by collectives, summed by op kind."""
    out = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        stype, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _bytes_of_shape(stype)
    return out


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))



def _active_params(cfg) -> float:
    """Analytic active-parameter count (no allocation)."""
    import jax as _jax
    shapes = M.abstract_init(cfg)
    total = sum(x.size for x in _jax.tree.leaves(shapes))
    if cfg.moe is not None:
        # expert weights: [E, D, F] x2 + [E, F, D] per layer
        e, k = cfg.moe.n_experts, cfg.moe.top_k
        expert = cfg.n_layers * 3 * e * cfg.d_model * cfg.d_ff
        total -= expert * (1 - k / e)
    return float(total)


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               mode: str = "stacked-rrs", verbose: bool = True,
               save_hlo: str = None) -> dict:
    cfg = get_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 1
    for a in mesh.axis_names:
        n_chips *= mesh.shape[a]

    # long_500k policy (DESIGN.md §4): native for sub-quadratic archs,
    # SWA-4096 variant for full-attention archs.
    window = "cfg"
    variant = ""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        window = 4096
        variant = "swa4096-variant"
    # stacked mode floor: one worker's full f32 gradient, model-sharded
    # only (N*4/tp bytes/chip). Switch to IB-RRS when that alone nears
    # HBM (llama3-405b: 101 GB; mixtral-8x7b: 11.7 GB).
    if shape.kind == "train" and mode.startswith("stacked"):
        n_params = _active_params(cfg) if cfg.moe is None else float(
            sum(x.size for x in jax.tree.leaves(M.abstract_init(cfg))))
        tp = mesh.shape["model"]
        if n_params * 4.0 / tp > 4e9:
            mode = "inloop"

    params_shapes = M.abstract_init(cfg)
    params_specs = S.param_specs(params_shapes, mesh)
    params_sh = _named(mesh, params_specs)
    params_in = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        params_shapes, params_sh)

    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        setup = make_train_step(cfg, mesh, mode=mode)
        import repro.optim as O
        optimizer = O.get(cfg.optimizer, lr=1e-3)
        opt_shapes = jax.eval_shape(optimizer.init, params_shapes)
        opt_sh = _named(mesh, setup.opt_specs)
        opt_in = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            opt_shapes, opt_sh)
        batch_sh = _named(mesh, S.batch_specs(specs, setup.batch_axes))
        batch_in = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            specs, batch_sh)
        key_in = jax.ShapeDtypeStruct((2,), jnp.uint32)
        lowered = jax.jit(
            setup.step_fn, donate_argnums=(0, 1),
            out_shardings=(params_sh, opt_sh, None),
        ).lower(params_in, opt_in, batch_in, key_in)
    elif shape.kind == "prefill":
        prefill_fn, _, _, _, batch_axes = make_serve_steps(
            cfg, mesh, shape=shape, window=window)
        batch_sh = _named(mesh, S.batch_specs(specs, batch_axes))
        batch_in = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            specs, batch_sh)
        _, _, cache_shapes, cache_spec_fn, _ = make_serve_steps(
            cfg, mesh, shape=shape, window=window)
        csp = _named(mesh, cache_spec_fn())
        logit_sh = NamedSharding(
            mesh, P(batch_axes, None,
                    "model" if cfg.vocab % mesh.shape["model"] == 0 else None))
        lowered = jax.jit(
            prefill_fn, out_shardings=(logit_sh, csp),
        ).lower(params_in, batch_in)
    else:  # decode
        _, decode_fn, cache_shapes, cache_spec_fn, batch_axes = \
            make_serve_steps(cfg, mesh, shape=shape, window=window)
        cs = cache_shapes()
        csp = _named(mesh, cache_spec_fn())
        cache_in = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            cs, csp)
        tok_sh = NamedSharding(mesh, P(batch_axes))
        tok_in = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32,
                                      sharding=tok_sh)
        logit_sh = NamedSharding(
            mesh, P(batch_axes,
                    "model" if cfg.vocab % mesh.shape["model"] == 0 else None))
        lowered = jax.jit(
            decode_fn, donate_argnums=(1,),
            out_shardings=(logit_sh, csp),
        ).lower(params_in, cache_in, tok_in)

    compiled = lowered.compile()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(compiled.as_text())
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # jax < 0.5 returns one dict per device program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    # Trip-count-aware totals (XLA's cost_analysis counts while bodies
    # once -- see hlo_cost module docstring). xla_* fields keep the raw
    # XLA numbers for cross-checking.
    hc = hlo_cost.analyze(hlo)
    flops = hc["flops"]
    bytes_hbm = hc["bytes"]
    coll = hc["collectives"]
    coll_total = hc["collective_bytes"]

    # analytic MODEL_FLOPS = 6 * N_active * tokens (fwd+bwd) or 2*N*tokens (fwd)
    cfg_obj = cfg
    n_active = _active_params(cfg_obj)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops_global = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops_global = 2.0 * n_active * tokens
    else:
        tokens = shape.global_batch  # one token per sequence
        model_flops_global = 2.0 * n_active * tokens
    model_flops_per_chip = model_flops_global / n_chips

    # roofline terms (seconds, per device program = per chip)
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_hbm / HBM_BW
    t_collective = coll_total / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    bottleneck = max(terms, key=terms.get)

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips, "mode": mode, "variant": variant,
        "flops_per_chip": flops, "hbm_bytes_per_chip": bytes_hbm,
        "collective_bytes_per_chip": coll_total,
        "collectives": coll,
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flops_ratio": model_flops_per_chip / max(flops, 1.0),
        "xla_flops": float(cost.get("flops", 0.0)),
        "xla_bytes": float(cost.get("bytes accessed", 0.0)),
        **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "peak_memory_bytes": getattr(mem, "temp_size_in_bytes", 0)
        + getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0)
        - getattr(mem, "alias_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
    }
    if verbose:
        print(f"== {arch} x {shape_name} on {result['mesh']} "
              f"(mode={mode}{' ' + variant if variant else ''}) ==")
        print("memory_analysis:", mem)
        print("cost_analysis: flops={:.3e} bytes={:.3e}".format(
            flops, bytes_hbm))
        print("collectives:", {k: f"{v:.3e}" for k, v in coll.items()})
        print("model_flops/chip={:.3e} useful_ratio={:.3f}".format(
            model_flops_per_chip, result["useful_flops_ratio"]))
        print("roofline: compute={:.3e}s memory={:.3e}s collective={:.3e}s"
              " -> bottleneck={}".format(
                  t_compute, t_memory, t_collective, result["bottleneck"]))
    return result


def write_metrics_jsonl(res: dict, path: str) -> None:
    """Append one ``kind: "dryrun"`` record to the shared telemetry
    JSONL (DESIGN.md §11): the HLO cost summary as ``launch.*`` gauges —
    so ``scripts/metrics_dump.py`` folds compile-time costs into the
    same Prometheus exposition as the runtime serve/train metrics — plus
    the full result dict for ``launch/report.py``."""
    from repro.obs.sinks import JsonlSink

    with JsonlSink(path) as sink:
        sink.write({
            "kind": "dryrun",
            "gauges": {
                "launch.compile_flops": res["flops_per_chip"],
                "launch.compile_hbm_bytes": res["hbm_bytes_per_chip"],
                "launch.compile_collective_bytes":
                    res["collective_bytes_per_chip"],
                "launch.compile_peak_memory_bytes": res["peak_memory_bytes"],
            },
            "meta": {"arch": res["arch"], "shape": res["shape"],
                     "mesh": res["mesh"], "mode": res["mode"]},
            "result": res,
        })


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True,
                    choices=sorted(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="stacked-rrs")
    ap.add_argument("--json", default=None)
    ap.add_argument("--metrics-jsonl", default=None,
                    help="append the cost summary to this telemetry JSONL "
                    "(obs.sinks wire format)")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()
    res = dryrun_one(args.arch, args.shape, multi_pod=args.multi_pod,
                     mode=args.mode, save_hlo=args.save_hlo)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1)
    if args.metrics_jsonl:
        write_metrics_jsonl(res, args.metrics_jsonl)


if __name__ == "__main__":
    main()
