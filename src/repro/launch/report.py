"""Render the §Dry-run / §Roofline tables from results/dryrun/*.json.

  PYTHONPATH=src python -m repro.launch.report [--dir results/dryrun] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ORDER_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ORDER_ARCHS = ["whisper-medium", "qwen3-1.7b", "starcoder2-7b",
               "phi-3-vision-4.2b", "zamba2-7b", "granite-moe-3b-a800m",
               "minitron-4b", "mamba2-2.7b", "mixtral-8x7b", "llama3-405b"]


def load(dir_):
    out = {}
    for f in glob.glob(os.path.join(dir_, "*.json")):
        try:
            d = json.load(open(f))
        except Exception:
            continue
        out[(d["arch"], d["shape"], d["mesh"])] = d
    return out


def load_jsonl(path):
    """Dry-run results from the shared telemetry JSONL (DESIGN.md §11):
    ``kind: "dryrun"`` records carry the full result dict alongside
    their ``launch.*`` gauges, so one artifact feeds both this report
    and ``scripts/metrics_dump.py``. Later records win (rerun = update).
    """
    from repro.obs.sinks import read_jsonl

    out = {}
    for rec in read_jsonl(path):
        if rec.get("kind") != "dryrun" or "result" not in rec:
            continue
        d = rec["result"]
        out[(d["arch"], d["shape"], d["mesh"])] = d
    return out


def fmt_s(x):
    if x == 0:
        return "0"
    for unit, scale in (("s", 1.0), ("ms", 1e-3), ("us", 1e-6)):
        if x >= scale:
            return f"{x/scale:.2f}{unit}"
    return f"{x:.1e}s"


def roofline_table(res, mesh="16x16", md=True):
    hdr = ["arch", "shape", "mode", "compute", "memory", "collective",
           "bottleneck", "useful", "peakGB", "fits16G"]
    rows = []
    for arch in ORDER_ARCHS:
        for shape in ORDER_SHAPES:
            d = res.get((arch, shape, mesh))
            if d is None:
                rows.append([arch, shape, "MISSING"] + [""] * 7)
                continue
            peak = d["peak_memory_bytes"] / 1e9
            rows.append([
                arch, shape,
                d["mode"] + (f" [{d['variant']}]" if d["variant"] else ""),
                fmt_s(d["compute_s"]), fmt_s(d["memory_s"]),
                fmt_s(d["collective_s"]), d["bottleneck"],
                f"{d['useful_flops_ratio']:.2f}", f"{peak:.1f}",
                "yes" if peak <= 16.0 else "NO",
            ])
    if md:
        lines = ["| " + " | ".join(hdr) + " |",
                 "|" + "---|" * len(hdr)]
        for r in rows:
            lines.append("| " + " | ".join(str(x) for x in r) + " |")
        return "\n".join(lines)
    w = [max(len(str(r[i])) for r in [hdr] + rows) for i in range(len(hdr))]
    lines = ["  ".join(str(h).ljust(w[i]) for i, h in enumerate(hdr))]
    for r in rows:
        lines.append("  ".join(str(x).ljust(w[i]) for i, x in enumerate(r)))
    return "\n".join(lines)


def multipod_status(res):
    lines = []
    for arch in ORDER_ARCHS:
        row = [arch]
        for shape in ORDER_SHAPES:
            d = res.get((arch, shape, "2x16x16"))
            row.append("ok" if d else "-")
        lines.append(row)
    out = ["| arch | " + " | ".join(ORDER_SHAPES) + " |",
           "|" + "---|" * 5]
    for r in lines:
        out.append("| " + " | ".join(r) + " |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--jsonl", default=None,
                    help="also load dryrun records from this telemetry "
                    "JSONL (obs.sinks wire format); overrides --dir dupes")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    res = load(args.dir)
    if args.jsonl:
        res.update(load_jsonl(args.jsonl))
    print(f"# loaded {len(res)} results from {args.dir}"
          f"{' + ' + args.jsonl if args.jsonl else ''}\n")
    print("## Roofline (single-pod 16x16, per chip)\n")
    print(roofline_table(res, "16x16", md=args.md))
    print("\n## Multi-pod (2x16x16) compile status\n")
    print(multipod_status(res))


if __name__ == "__main__":
    main()
