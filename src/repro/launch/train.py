"""Training launcher.

Runs real (allocating) robust training on whatever devices exist —
typically a handful of host CPU devices for local runs, the production
mesh on a pod. For the 512-device compile-only path use dryrun.py.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 50 --data 4 --model 2 --aggregator vrmom --byzantine 0.25
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import optim as O
from repro.checkpoint import save as ckpt_save
from repro.configs import get as get_arch
from repro.data import lm_batch, shard_batch
from repro.dist import sharding as S
from repro.models import model as M
from repro.core.estimator import Estimator
from repro.obs.metrics import now
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale variant of the arch")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--data", type=int, default=0,
                    help="data mesh axis (0 = all devices)")
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--aggregator", default="vrmom",
                    choices=["vrmom", "mom", "trimmed_mean", "mean"])
    ap.add_argument("--mode", default="stacked-rrs")
    ap.add_argument("--K", type=int, default=10)
    ap.add_argument("--beta", type=float, default=None,
                    help="trimmed_mean trim fraction per end (default: "
                         "0.1, raised to 1/workers when 0.1 trims no rows)")
    ap.add_argument("--byzantine", type=float, default=0.0)
    ap.add_argument("--attack", default="gaussian")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    n_dev = len(jax.devices())
    data = args.data or max(n_dev // args.model, 1)
    mesh = jax.make_mesh((data, args.model), ("data", "model"))
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    n_workers = data  # worker axes = ("data",) on this 2-axis mesh
    beta = args.beta if args.beta is not None else max(0.1, 1.0 / n_workers)
    setup = make_train_step(
        cfg, mesh,
        estimator=Estimator(method=args.aggregator, K=args.K, beta=beta),
        mode=args.mode, lr=args.lr, byzantine_frac=args.byzantine,
        attack=args.attack)
    optimizer = O.get(cfg.optimizer, lr=args.lr)

    params = M.init(jax.random.PRNGKey(0), cfg)
    params = jax.device_put(params, S.to_named(mesh, setup.params_specs))
    opt_state = jax.jit(optimizer.init)(params)
    step = jax.jit(setup.step_fn)

    n_params = M.param_count(params)
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M mesh={dict(mesh.shape)} "
          f"workers={setup.n_workers} aggregator={args.aggregator} "
          f"mode={args.mode} byzantine={args.byzantine} attack={args.attack}")

    t0 = now()
    for i in range(args.steps):
        batch = shard_batch(lm_batch(cfg, i, args.batch, args.seq), mesh,
                            setup.batch_axes)
        params, opt_state, loss = step(params, opt_state, batch,
                                       jax.random.PRNGKey(i))
        if i % args.log_every == 0 or i == args.steps - 1:
            dt = now() - t0
            print(f"step {i:4d} loss {float(loss):.4f} "
                  f"({dt/(i+1):.2f} s/step)")
    if args.checkpoint:
        ckpt_save(args.checkpoint, {"params": params, "opt": opt_state})
        print("checkpoint saved to", args.checkpoint)


if __name__ == "__main__":
    main()
