"""Run the full (arch x shape x mesh) dry-run sweep, one subprocess per
combo (jax device count is locked per process), resumable via JSON files.

  PYTHONPATH=src python -m repro.launch.sweep --out results/dryrun
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from repro.obs.metrics import now

ARCHS = ["qwen3-1.7b", "mamba2-2.7b", "granite-moe-3b-a800m", "minitron-4b",
         "phi-3-vision-4.2b", "whisper-medium", "starcoder2-7b",
         "mixtral-8x7b", "zamba2-7b", "llama3-405b"]
SHAPES = ["decode_32k", "long_500k", "prefill_32k", "train_4k"]


def combos(include_multipod=True):
    for multi in ([False, True] if include_multipod else [False]):
        for shape in SHAPES:
            for arch in ARCHS:
                yield arch, shape, multi


def run_one(arch, shape, multi, out_dir, timeout=2400):
    mesh = "2x16x16" if multi else "16x16"
    name = f"{arch}__{shape}__{mesh}.json"
    path = os.path.join(out_dir, name)
    if os.path.exists(path):
        return "cached", path
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--json", path]
    if multi:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    t0 = now()
    try:
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           timeout=timeout)
    except subprocess.TimeoutExpired:
        with open(path + ".err", "w") as f:
            f.write("TIMEOUT")
        return "timeout", path
    if r.returncode != 0:
        with open(path + ".err", "w") as f:
            f.write(r.stdout[-3000:] + "\n=== STDERR ===\n" + r.stderr[-6000:])
        return "failed", path
    return f"ok({now()-t0:.0f}s)", path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--single-pod-only", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    todo = list(combos(include_multipod=not args.single_pod_only))
    for i, (arch, shape, multi) in enumerate(todo):
        status, path = run_one(arch, shape, multi, args.out)
        print(f"[{i+1}/{len(todo)}] {arch} x {shape} x "
              f"{'2x16x16' if multi else '16x16'}: {status}", flush=True)


if __name__ == "__main__":
    main()
