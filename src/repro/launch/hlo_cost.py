"""Trip-count-aware cost model over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE
(verified empirically — a 10-iteration scan of a matmul reports 1
matmul's flops). Our models scan over layers and microbatches, so FLOPs
and collective bytes would be undercounted by orders of magnitude.

This module parses the optimized HLO, builds the computation call graph
(while bodies with trip counts extracted from their loop conditions,
fusions, calls, conditionals) and accumulates, per enclosing-loop
multiplicity:

  * flops            — dot ops: 2 * prod(out dims) * prod(contracting),
                       conv ops: 2 * prod(out) * prod(kernel);
  * bytes            — proxy for HBM traffic: output buffer sizes of
                       non-plumbing ops (tuple/GTE/bitcast excluded);
  * collective bytes — operand sizes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute.

Operand shapes are resolved through a per-computation symbol table
(optimized HLO references operands by name only).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"          # result name
    r"((?:\([^=]*?\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"  # shape
    r"([\w\-]+)\((.*)$")                               # kind, rest

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_BYTE_SKIP = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "call", "fusion", "broadcast", "iota", "copy-start", "copy-done",
}


def _dims_product(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _parse_shapes(text: str):
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(text: str) -> float:
    return float(sum(_dims_product(d) * _DTYPE_BYTES[dt]
                     for dt, d in _parse_shapes(text)))


@dataclasses.dataclass
class Op:
    name: str
    shape: str       # result shape text
    kind: str
    rest: str        # everything after '<kind>('


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + mult * v


def parse(hlo: str) -> Dict[str, Dict[str, Op]]:
    comps: Dict[str, Dict[str, Op]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        line = re.sub(r"/\*.*?\*/", "", line)  # strip /*index=N*/ comments
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and "=" not in stripped.split("(")[0]:
                m = _HDR_RE.match(stripped)
                if m:
                    cur = m.group(1)
                    comps[cur] = {}
            continue
        if stripped == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape, kind, rest = m.groups()
        comps[cur][name] = Op(name=name, shape=shape, kind=kind, rest=rest)
    return comps


def _operand_names(rest: str) -> List[str]:
    head = rest.split(")")[0]
    return re.findall(r"%([\w\.\-]+)", head)


def _dot_flops(op: Op, table: Dict[str, Op]) -> float:
    out_n = sum(_dims_product(d) for _, d in _parse_shapes(op.shape))
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    names = _operand_names(op.rest)
    if not m or not names or names[0] not in table:
        return 2.0 * out_n
    lhs_shapes = _parse_shapes(table[names[0]].shape)
    if not lhs_shapes:
        return 2.0 * out_n
    lhs_dims = lhs_shapes[0][1]
    k = 1
    if m.group(1):
        for i in m.group(1).split(","):
            idx = int(i)
            if idx < len(lhs_dims):
                k *= lhs_dims[idx]
    return 2.0 * out_n * k


def _conv_flops(op: Op, table: Dict[str, Op]) -> float:
    out_n = sum(_dims_product(d) for _, d in _parse_shapes(op.shape))
    names = _operand_names(op.rest)
    if len(names) < 2 or names[1] not in table:
        return 2.0 * out_n
    kern = sum(_dims_product(d) for _, d in _parse_shapes(table[names[1]].shape))
    return 2.0 * out_n * kern


def _max_s32_const(ops: Dict[str, Op]) -> int:
    best = 1
    for op in ops.values():
        if op.kind == "constant" and op.shape.startswith("s32"):
            m = re.match(r"\s*(-?\d+)", op.rest)
            if m and int(m.group(1)) > best:
                best = int(m.group(1))
    return best


class HloCost:
    """Bytes accounting: HBM traffic is modelled at fusion boundaries —
    a fusion op contributes its operands (reads) + output (write); its
    internal ops contribute nothing (register/VMEM-resident). Standalone
    compute ops contribute operands + output the same way."""

    def __init__(self, hlo_text: str):
        self.comps = parse(hlo_text)
        self._memo: Dict[tuple, Cost] = {}

    def _cost(self, comp: str, stack=(), count_bytes: bool = True) -> Cost:
        memo_key = (comp, count_bytes)
        if memo_key in self._memo:
            return self._memo[memo_key]
        if comp not in self.comps or comp in stack:
            return Cost()
        table = self.comps[comp]
        total = Cost()
        for op in table.values():
            k = op.kind
            if k == "dot":
                total.flops += _dot_flops(op, table)
            elif k == "convolution":
                total.flops += _conv_flops(op, table)
            ck = next((c for c in _COLLECTIVES
                       if k == c or k.startswith(c + "-")), None)
            if ck:
                names = _operand_names(op.rest)
                b = sum(_shape_bytes(table[n].shape) for n in names
                        if n in table)
                total.coll[ck] = total.coll.get(ck, 0.0) + b
            if count_bytes and (k not in _BYTE_SKIP or k == "fusion"):
                # Output-only accounting x2 (write + ~one read downstream).
                # Operands are NOT summed: fusions often take whole
                # stacked scan buffers and slice internally, which would
                # attribute the full 28-layer buffer to every consumer.
                total.bytes += 2.0 * _shape_bytes(op.shape)
            if k == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", op.rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                trips = _max_s32_const(self.comps.get(mc.group(1), {})) \
                    if mc else 1
                if mb:
                    total.add(self._cost(mb.group(1), stack + (comp,),
                                         count_bytes), trips)
                if mc:
                    total.add(self._cost(mc.group(1), stack + (comp,),
                                         count_bytes), trips)
            elif k in ("fusion", "call", "custom-call", "reduce",
                       "reduce-window", "scatter", "select-and-scatter",
                       "sort", "map", "conditional", "all-reduce"):
                inner_bytes = count_bytes and k == "call"
                for attr in ("calls", "to_apply"):
                    m = re.search(attr + r"=%?([\w\.\-]+)", op.rest)
                    if m:
                        total.add(self._cost(m.group(1), stack + (comp,),
                                             inner_bytes))
                m = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
                if m:
                    subs = [self._cost(c.strip().lstrip("%"),
                                       stack + (comp,), count_bytes)
                            for c in m.group(1).split(",")]
                    if subs:  # worst-case branch
                        worst = max(subs, key=lambda c: c.flops + c.bytes)
                        total.add(worst)
        self._memo[comp] = total
        return total

    def entry_cost(self) -> Cost:
        called = set()
        for ops in self.comps.values():
            for op in ops.values():
                for m in re.finditer(
                        r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)",
                        op.rest):
                    called.add(m.group(1))
                m = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
                if m:
                    for c in m.group(1).split(","):
                        called.add(c.strip().lstrip("%"))
        best = Cost()
        for name in self.comps:
            if name in called:
                continue
            c = self._cost(name)
            if c.flops + c.bytes >= best.flops + best.bytes:
                best = c
        return best


def analyze(hlo_text: str) -> dict:
    c = HloCost(hlo_text).entry_cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collectives": dict(c.coll),
        "collective_bytes": float(sum(c.coll.values())),
    }
