"""Flash-attention forward Pallas kernel (online softmax).

Motivated by the §Roofline result that every prefill_32k pair is
memory-bound on f32 score traffic: the fused kernel keeps the running
(m, l, acc) softmax state in VMEM scratch and never writes scores to HBM
— one pass over K/V per query block instead of materializing
[blk_q, T] f32 three times (scores, probs, and their backward copies).

Layout: grid (B*H, n_q_blocks, n_kv_blocks); the kv grid axis is the
innermost (sequential on TPU), accumulating into scratch; the output
block is written on the last kv step. Blocks are VMEM-resident
([blk, dh] with dh = 64..128, MXU-aligned).

Validated against ref.ref_attention in interpret mode (CPU) across
shapes/dtypes/causality — tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, blk_q, blk_k, n_k, t_valid):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # [blk_q, dh]
    k = k_ref[0].astype(jnp.float32)  # [blk_k, dh]
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T) * scale  # [blk_q, blk_k] f32

    if causal or t_valid % blk_k:
        k_pos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    if causal:
        q_pos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)
    if t_valid % blk_k:
        # key-validity mask: T (static) isn't tile-divisible, so the
        # last kv block carries zero-padded keys — mask them regardless
        # of causality (the non-causal pad_k case used to silently fall
        # back to the jnp reference; now it's in-kernel).
        s = jnp.where(k_pos < t_valid, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc = acc_scr[...] * alpha[:, None] + jnp.dot(p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == n_k - 1)
    def _done():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "blk_q", "blk_k",
                                             "n_heads", "n_rep", "interpret"))
def _flash_bh(q, k, v, causal, blk_q, blk_k, n_heads, n_rep, interpret):
    """q: [B*H, S, dh]; k/v: [B*Hkv, T, dh] -> [B*H, S, dh].

    GQA stays grouped on the wire: K/V arrive at Hkv heads and the K/V
    BlockSpec index maps collapse each query head to its kv group
    (h // n_rep), so the kernel reads the same VMEM K/V block for all
    n_rep query heads of a group and K/V are never materialized at H
    (reprolint RL002).
    """
    BH, S, dh = q.shape
    T = k.shape[1]
    n_kv = n_heads // n_rep
    blk_q = min(blk_q, S)
    blk_k = min(blk_k, T)
    pad_q = (-S) % blk_q
    pad_k = (-T) % blk_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # zero-padded keys are excluded by the in-kernel validity mask
        # (t_valid = T is static, so the mask costs one compare on the
        # last kv block only)
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    Sq, Tk = S + pad_q, T + pad_k
    n_q, n_k = Sq // blk_q, Tk // blk_k
    scale = 1.0 / (dh ** 0.5)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, blk_q=blk_q,
        blk_k=blk_k, n_k=n_k, t_valid=T)

    out = pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec(
                (1, blk_k, dh),
                lambda b, i, j: ((b // n_heads) * n_kv
                                 + (b % n_heads) // n_rep, j, 0)),
            pl.BlockSpec(
                (1, blk_k, dh),
                lambda b, i, j: ((b // n_heads) * n_kv
                                 + (b % n_heads) // n_rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]


def flash_attention(q, k, v, *, causal=True, blk_q=256, blk_k=256,
                    interpret=None):
    """q: [B, S, H, dh]; k/v: [B, T, Hkv, dh] -> [B, S, H, dh].

    GQA is handled grouped: K/V stay at Hkv heads end-to-end and the
    grid's flat batch*head axis maps each query head to its kv group
    via the BlockSpec index map, so K/V HBM traffic is Hkv/H of the
    repeated layout. Every shape is expressed in-kernel — non-divisible
    T (causal or not) is covered by the static key-validity mask, so
    there is no reference fallback. Dispatch policy (which model layers
    run this vs the chunked jnp ``mha``) lives in
    ``models/attn_backend.py``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, S, H, dh = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    if H % Hkv:
        raise ValueError(f"H={H} not a multiple of Hkv={Hkv}")
    qf = jnp.moveaxis(q, 2, 1).reshape(B * H, S, dh)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, T, dh)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, T, dh)
    out = _flash_bh(qf, kf, vf, causal, blk_q, blk_k, H, H // Hkv,
                    bool(interpret))
    return jnp.moveaxis(out.reshape(B, H, S, dh), 1, 2)
