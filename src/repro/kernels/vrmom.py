"""Fused robust-aggregation kernel family as Pallas TPU kernels.

The paper's only compute hot-spot is the aggregation itself (Remark 1:
O(m+n) vs O(m log m)); on TPU the aggregation of an m-way stack of
gradient chunks or replica logits is purely memory-bound, so the
kernel's job is to do the whole estimate in ONE pass over the [m, C]
stack held in VMEM — a single HBM read of the stack and a single [C]
write, instead of the >= 4 passes (median, abs-dev, median, correction)
a composition of jnp ops would take.

One kernel, four methods (DESIGN.md §7): ``median``/``mom``, ``vrmom``,
``trimmed_mean`` and ``mean`` all share the entry point. The sorted rows
are already resident in VMEM for the median, so the trimmed mean (a
static slice-and-average of the same sorted block) is essentially free,
and the mean skips the network entirely but reuses the tiling.

TPU adaptation choices (DESIGN.md §6/§7):

* The worker axis m is small and static (replica count or the data/pod
  mesh axes), so order statistics are computed with an **odd-even
  transposition sorting network** over the sublane axis: m compare-
  exchange passes of stride-2 slices — no gathers (Pallas TPU has no
  general gather), no data-dependent control flow, VPU-friendly.
* Rows are padded to the next even/static size with +inf so the honest
  order statistics live in the first m slots at *static* indices.
* Quantile counts use Sum_k 1(z <= Delta_k) with Delta_k baked in as
  compile-time constants (K static), accumulated k-at-a-time to keep the
  VMEM footprint at one [m, C_tile] block.

Grid: 1-D over coordinate tiles; block [m_pad, C_TILE] in VMEM. Batched
inputs ([m, B, V] logit stacks from the replicated decode path) are
handled by the entry-point reshape: every estimator is coordinate-wise,
so trailing dims flatten into the coordinate axis — the serve decode
``lax.scan`` calls the same kernel the gradient path uses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.vrmom import _MAD_CONST, _deltas_cached, psi_sum

DEFAULT_TILE = 512        # compiled TPU path: [m_pad, 512] block in VMEM
INTERPRET_TILE = 65536    # interpret mode: amortize per-grid-step
                          # interpreter overhead (host memory, no VMEM cap)

_NEG_INF = -1e30      # sampling mask for padded vocab columns
_BIG_IDX = 2 ** 30    # index sentinel for argmax/top-k tie-break

__all__ = [
    "aggregate_pallas",
    "aggregate_sample_pallas",
    "vrmom_pallas",
    "mom_pallas",
    "trimmed_mean_pallas",
    "mean_pallas",
]


def _sort_rows(x, m_pad):
    """Odd-even transposition sort along axis 0 (ascending), static network."""
    for p in range(m_pad):
        if p % 2 == 0:  # even phase: pairs (0,1),(2,3),...
            a, b = x[0::2], x[1::2]
            lo, hi = jnp.minimum(a, b), jnp.maximum(a, b)
            x = jnp.stack([lo, hi], axis=1).reshape(x.shape)
        else:  # odd phase: pairs (1,2),(3,4),...; first/last rows fixed
            if m_pad <= 2:
                continue
            mid = x[1 : m_pad - 1]
            a, b = mid[0::2], mid[1::2]
            lo, hi = jnp.minimum(a, b), jnp.maximum(a, b)
            mid = jnp.stack([lo, hi], axis=1).reshape(mid.shape)
            x = jnp.concatenate([x[0:1], mid, x[m_pad - 1 : m_pad]], axis=0)
    return x


def _median_of_sorted(xs, m):
    return 0.5 * (xs[(m - 1) // 2] + xs[m // 2])


def _agg_block(x, *, m, m_pad, method, K, k_trim, eps):
    """Aggregate one VMEM-resident block over axis 0: [m_pad, ...] -> [...].

    Shared by the plain aggregation kernel and the fused sampling-tail
    kernel — both run the exact same op sequence, so fused greedy tokens
    are bit-identical to argmax over the unfused aggregate.
    """
    if method == "mean":
        # padded rows are +inf: mask them out instead of sorting
        row_valid = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0) < m
        return jnp.sum(jnp.where(row_valid, x, 0.0), axis=0) / m
    xs = _sort_rows(x, m_pad)  # +inf padding sorts past the honest rows
    if method == "trimmed_mean":
        # rows k_trim..m-k_trim-1 of the already-sorted block: the trim
        # is a static slice, so the trimmed mean costs one extra sum.
        seg = xs[k_trim : m - k_trim]
        return jnp.sum(seg, axis=0) / seg.shape[0]
    med = _median_of_sorted(xs, m)
    if method == "median":
        return med
    # vrmom: MAD scale + quantile-count correction, same VMEM block
    dev = jnp.abs(x - med[None])  # padded rows are +inf already
    devs = _sort_rows(dev, m_pad)
    mad = _median_of_sorted(devs, m)
    s = mad / _MAD_CONST
    z = (x - med[None]) / jnp.maximum(s, eps)[None]
    row_valid = jax.lax.broadcasted_iota(jnp.int32, z.shape, 0) < m
    deltas = _deltas_cached(K)
    counts = jnp.zeros_like(z)
    for k in range(K):
        counts = counts + (z <= jnp.float32(deltas[k])).astype(jnp.float32)
    summand = jnp.where(row_valid, counts - K / 2.0, 0.0)
    total = jnp.sum(summand, axis=0)
    out = med - s * total / (m * psi_sum(K))
    return jnp.where(s <= eps, med, out)


def _kernel(x_ref, o_ref, *, m, m_pad, method, K, k_trim, eps):
    x = x_ref[...].astype(jnp.float32)  # [m_pad, C]
    out = _agg_block(x, m=m, m_pad=m_pad, method=method, K=K,
                     k_trim=k_trim, eps=eps)
    o_ref[...] = out.astype(o_ref.dtype)


def _pad_rows(x, m_pad):
    m = x.shape[0]
    if m_pad == m:
        return x
    pad = jnp.full((m_pad - m,) + x.shape[1:], jnp.inf, dtype=x.dtype)
    return jnp.concatenate([x, pad], axis=0)


@functools.partial(
    jax.jit,
    static_argnames=("method", "K", "k_trim", "tile", "interpret", "eps"),
)
def _agg_2d(x, method: str, K: int, k_trim: int, tile: int, interpret: bool,
            eps: float):
    m, c = x.shape
    m_pad = m + (m % 2)  # sorting network wants an even row count
    tile = min(tile, max(c, 1))
    c_pad = -(-c // tile) * tile
    xp = _pad_rows(x, m_pad)
    if c_pad != c:
        xp = jnp.pad(xp, ((0, 0), (0, c_pad - c)), constant_values=1.0)
    out = pl.pallas_call(
        functools.partial(_kernel, m=m, m_pad=m_pad, method=method, K=K,
                          k_trim=k_trim, eps=eps),
        grid=(c_pad // tile,),
        in_specs=[pl.BlockSpec((m_pad, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((c_pad,), x.dtype),
        interpret=interpret,
    )(xp)
    return out[:c]


def _topk_rows(vals, idxs, k):
    """Row-wise top-k of (value, index) pairs along axis 1.

    Descending by value, ties broken toward the smaller index — the same
    order ``jax.lax.top_k`` produces — via k static max-extraction
    passes (no sort, no gather). Returns ([B, k], [B, k])."""
    tv, ti = [], []
    for _ in range(k):
        mx = jnp.max(vals, axis=1, keepdims=True)
        sel = jnp.min(jnp.where(vals == mx, idxs, _BIG_IDX),
                      axis=1, keepdims=True)
        tv.append(mx)
        ti.append(sel)
        vals = jnp.where(idxs == sel, _NEG_INF, vals)
    return jnp.concatenate(tv, axis=1), jnp.concatenate(ti, axis=1)


def _tail_kernel(x_ref, *refs, m, m_pad, method, K, k_trim, eps, tile,
                 v_total, n_vt, top_k, with_agg):
    """Aggregation + sampling epilogue on one [m_pad, B, tile] block.

    The aggregate is computed once per vocab tile; the sampling tail
    (running argmax for greedy, running top-k otherwise) reuses the same
    VMEM-resident result, carrying its state across vocab tiles in
    scratch and writing token ids on the last tile."""
    refs = list(refs)
    agg_ref = refs.pop(0) if with_agg else None
    if top_k == 0:
        tok_ref, bv_scr, bi_scr = refs
    else:
        topv_ref, topi_ref, bv_scr, bi_scr = refs
    vi = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)  # [m_pad, B, tile]
    agg = _agg_block(x, m=m, m_pad=m_pad, method=method, K=K,
                     k_trim=k_trim, eps=eps)  # [B, tile]
    if with_agg:
        agg_ref[...] = agg.astype(agg_ref.dtype)
    # mask the padded tail of the vocab axis so it can never win the
    # argmax/top-k (the pad value is a live logit magnitude, not -inf)
    pos = vi * tile + jax.lax.broadcasted_iota(jnp.int32, agg.shape, 1)
    a = jnp.where(pos < v_total, agg, _NEG_INF)

    @pl.when(vi == 0)
    def _init():
        bv_scr[...] = jnp.full(bv_scr.shape, _NEG_INF, jnp.float32)
        bi_scr[...] = jnp.zeros(bi_scr.shape, jnp.int32)

    if top_k == 0:
        tile_max = jnp.max(a, axis=1, keepdims=True)  # [B, 1]
        tile_idx = jnp.min(jnp.where(a == tile_max, pos, _BIG_IDX),
                           axis=1, keepdims=True)
        # strict >: an equal max in a later tile never displaces the
        # earlier index, matching jnp.argmax first-occurrence ties
        better = tile_max > bv_scr[...]
        bi_scr[...] = jnp.where(better, tile_idx, bi_scr[...])
        bv_scr[...] = jnp.where(better, tile_max, bv_scr[...])

        @pl.when(vi == n_vt - 1)
        def _write_tok():
            tok_ref[...] = bi_scr[:, 0]
    else:
        tv, ti = _topk_rows(a, pos, top_k)
        mv, mi = _topk_rows(jnp.concatenate([bv_scr[...], tv], axis=1),
                            jnp.concatenate([bi_scr[...], ti], axis=1),
                            top_k)
        bv_scr[...] = mv
        bi_scr[...] = mi

        @pl.when(vi == n_vt - 1)
        def _write_topk():
            topv_ref[...] = bv_scr[...]
            topi_ref[...] = bi_scr[...]


@functools.partial(
    jax.jit,
    static_argnames=("method", "K", "k_trim", "tile", "interpret", "eps",
                     "top_k", "with_agg"),
)
def _tail_3d(x, method: str, K: int, k_trim: int, tile: int, interpret: bool,
             eps: float, top_k: int, with_agg: bool):
    m, b, v = x.shape
    m_pad = m + (m % 2)  # sorting network wants an even row count
    tile = max(min(tile, max(v, 1)), max(top_k, 1))
    v_pad = -(-v // tile) * tile
    n_vt = v_pad // tile
    xp = _pad_rows(x, m_pad)
    if v_pad != v:
        xp = jnp.pad(xp, ((0, 0), (0, 0), (0, v_pad - v)),
                     constant_values=1.0)
    out_shape, out_specs = [], []
    if with_agg:
        out_shape.append(jax.ShapeDtypeStruct((b, v_pad), x.dtype))
        out_specs.append(pl.BlockSpec((b, tile), lambda i: (0, i)))
    if top_k == 0:
        out_shape.append(jax.ShapeDtypeStruct((b,), jnp.int32))
        out_specs.append(pl.BlockSpec((b,), lambda i: (0,)))
        scratch = [pltpu.VMEM((b, 1), jnp.float32),
                   pltpu.VMEM((b, 1), jnp.int32)]
    else:
        out_shape.append(jax.ShapeDtypeStruct((b, top_k), jnp.float32))
        out_specs.append(pl.BlockSpec((b, top_k), lambda i: (0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((b, top_k), jnp.int32))
        out_specs.append(pl.BlockSpec((b, top_k), lambda i: (0, 0)))
        scratch = [pltpu.VMEM((b, top_k), jnp.float32),
                   pltpu.VMEM((b, top_k), jnp.int32)]
    outs = pl.pallas_call(
        functools.partial(_tail_kernel, m=m, m_pad=m_pad, method=method,
                          K=K, k_trim=k_trim, eps=eps, tile=tile,
                          v_total=v, n_vt=n_vt, top_k=top_k,
                          with_agg=with_agg),
        grid=(n_vt,),
        in_specs=[pl.BlockSpec((m_pad, b, tile), lambda i: (0, 0, i))],
        out_specs=tuple(out_specs),
        out_shape=tuple(out_shape),
        scratch_shapes=scratch,
        interpret=interpret,
    )(xp)
    outs = list(outs)
    agg = outs.pop(0)[:, :v] if with_agg else None
    if top_k == 0:
        return agg, outs[0]
    return agg, outs[0], outs[1]


def _default_interpret():
    return jax.default_backend() != "tpu"


def aggregate_pallas(x, method: str = "vrmom", K: int = 10, beta: float = 0.1,
                     tile=None, interpret=None, eps: float = 1e-12):
    """Fused aggregation over axis 0: ``[m, ...] -> [...]``.

    ``method``: median/mom | vrmom | trimmed_mean | mean. Trailing dims
    are coordinates — ``[m, B, V]`` logit stacks and ``[m, C]`` gradient
    chunks take the same path. ``tile=None`` picks per mode: a
    VMEM-sized block when compiled, a wide block when interpreted (the
    per-grid-step interpreter overhead dominates otherwise —
    ``BENCH_agg.json``). Dispatch policy lives in
    ``core.estimator.Estimator``; this is the execution entry point.
    """
    method, k_trim, tile, interpret = _resolve_call(
        method, beta, x.shape[0], tile, interpret)
    shape = x.shape[1:]
    x2 = x.reshape(x.shape[0], -1)
    from ..obs.trace import named_span

    with named_span("kernels.aggregate"):
        out = _agg_2d(x2, method=method, K=K, k_trim=k_trim, tile=tile,
                      interpret=interpret, eps=eps)
    return out.reshape(shape)


def _resolve_call(method, beta, m, tile, interpret):
    if interpret is None:
        interpret = _default_interpret()
    if tile is None:
        tile = INTERPRET_TILE if interpret else DEFAULT_TILE
    method = "median" if method == "mom" else method
    if method not in ("median", "vrmom", "trimmed_mean", "mean"):
        raise ValueError(f"no fused kernel for method {method!r}")
    k_trim = 0
    if method == "trimmed_mean":
        k_trim = int(beta * m)
        if k_trim == 0 or m - 2 * k_trim < 1:
            raise ValueError(
                f"trimmed_mean kernel: beta={beta} at m={m} trims "
                f"{k_trim} rows per end — spec must be validated "
                f"(Estimator.validate) before dispatch")
    return method, k_trim, tile, bool(interpret)


def aggregate_sample_pallas(x, method: str = "vrmom", K: int = 10,
                            beta: float = 0.1, top_k: int = 0, tile=None,
                            interpret=None, eps: float = 1e-12,
                            with_agg: bool = True):
    """Fused aggregation + sampling tail over a ``[m, B, V]`` logit stack.

    One Pallas dispatch does what the unfused robust-decode tail did in
    two (aggregate kernel, then a jnp argmax/top-k pass over the [B, V]
    aggregate written back to HBM): the sampling epilogue runs on the
    aggregate while it is still VMEM-resident.

    Returns ``(agg, tok)`` for ``top_k == 0`` — greedy, ``tok[b]``
    bit-identical to ``jnp.argmax(agg[b])`` — or ``(agg, topv, topi)``
    for ``top_k > 0`` with the ``jax.lax.top_k`` value/index order, so a
    categorical draw over ``topv`` reproduces the masked-vocab top-k
    sampling distribution. ``with_agg=False`` skips the [B, V] aggregate
    write entirely (greedy serve steps with diagnostics off) and returns
    ``agg=None``.
    """
    if x.ndim != 3:
        raise ValueError(f"fused tail wants [m, B, V] stacks, got {x.shape}")
    if not 0 <= top_k <= x.shape[-1]:
        raise ValueError(f"top_k={top_k} out of range for V={x.shape[-1]}")
    method, k_trim, tile, interpret = _resolve_call(
        method, beta, x.shape[0], tile, interpret)
    from ..obs.trace import named_span

    with named_span("kernels.aggregate_sample"):
        return _tail_3d(x, method=method, K=K, k_trim=k_trim, tile=tile,
                        interpret=interpret, eps=eps, top_k=int(top_k),
                        with_agg=bool(with_agg))


def vrmom_pallas(x, K: int = 10, tile=None, interpret=None,
                 eps: float = 1e-12):
    """Fused VRMOM over axis 0. x: [m, ...] -> [...]. MAD scale."""
    return aggregate_pallas(x, "vrmom", K=K, tile=tile, interpret=interpret,
                            eps=eps)


def mom_pallas(x, tile=None, interpret=None):
    """Fused coordinate-wise median over axis 0."""
    return aggregate_pallas(x, "median", tile=tile, interpret=interpret)


def trimmed_mean_pallas(x, beta: float = 0.1, tile=None, interpret=None):
    """Fused coordinate-wise beta-trimmed mean over axis 0."""
    return aggregate_pallas(x, "trimmed_mean", beta=beta, tile=tile,
                            interpret=interpret)


def mean_pallas(x, tile=None, interpret=None):
    """Coordinate-wise mean over axis 0 (shares the kernel tiling)."""
    return aggregate_pallas(x, "mean", tile=tile, interpret=interpret)
