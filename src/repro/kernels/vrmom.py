"""Fused VRMOM / MOM aggregation as a Pallas TPU kernel.

The paper's only compute hot-spot is the aggregation itself (Remark 1:
O(m+n) vs O(m log m)); on TPU the aggregation of an m-way stack of
gradient chunks is purely memory-bound, so the kernel's job is to do the
median + MAD + quantile-count correction in ONE pass over the [m, C]
stack held in VMEM — a single HBM read of the stack and a single [C]
write, instead of the >= 4 passes (median, abs-dev, median, correction)
a composition of jnp ops would take.

TPU adaptation choices (DESIGN.md §6):

* The worker axis m is small and static (16 or 32 = the data/pod×data
  mesh axes), so order statistics are computed with an **odd-even
  transposition sorting network** over the sublane axis: m compare-
  exchange passes of stride-2 slices — no gathers (Pallas TPU has no
  general gather), no data-dependent control flow, VPU-friendly.
* Rows are padded to the next even/static size with +inf so the honest
  order statistics live in the first m slots at *static* indices.
* Quantile counts use Sum_k 1(z <= Delta_k) with Delta_k baked in as
  compile-time constants (K static), accumulated k-at-a-time to keep the
  VMEM footprint at one [m, C_tile] block.

Grid: 1-D over coordinate tiles; block [m_pad, C_TILE] in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.vrmom import _deltas_cached, psi_sum

_MAD_CONST = 0.6744897501960817
DEFAULT_TILE = 512


def _sort_rows(x, m_pad):
    """Odd-even transposition sort along axis 0 (ascending), static network."""
    for p in range(m_pad):
        if p % 2 == 0:  # even phase: pairs (0,1),(2,3),...
            a, b = x[0::2], x[1::2]
            lo, hi = jnp.minimum(a, b), jnp.maximum(a, b)
            x = jnp.stack([lo, hi], axis=1).reshape(x.shape)
        else:  # odd phase: pairs (1,2),(3,4),...; first/last rows fixed
            if m_pad <= 2:
                continue
            mid = x[1 : m_pad - 1]
            a, b = mid[0::2], mid[1::2]
            lo, hi = jnp.minimum(a, b), jnp.maximum(a, b)
            mid = jnp.stack([lo, hi], axis=1).reshape(mid.shape)
            x = jnp.concatenate([x[0:1], mid, x[m_pad - 1 : m_pad]], axis=0)
    return x


def _median_of_sorted(xs, m):
    return 0.5 * (xs[(m - 1) // 2] + xs[m // 2])


def _kernel(x_ref, o_ref, *, m, m_pad, K, vr, eps):
    x = x_ref[...].astype(jnp.float32)  # [m_pad, C]
    xs = _sort_rows(x, m_pad)
    med = _median_of_sorted(xs, m)  # [C]
    if not vr:
        o_ref[...] = med.astype(o_ref.dtype)
        return
    dev = jnp.abs(x - med[None, :])  # padded rows are +inf already
    devs = _sort_rows(dev, m_pad)
    mad = _median_of_sorted(devs, m)
    s = mad / _MAD_CONST
    z = (x - med[None, :]) / jnp.maximum(s, eps)[None, :]
    row_valid = jax.lax.broadcasted_iota(jnp.int32, z.shape, 0) < m
    deltas = _deltas_cached(K)
    counts = jnp.zeros_like(z)
    for k in range(K):
        counts = counts + (z <= jnp.float32(deltas[k])).astype(jnp.float32)
    summand = jnp.where(row_valid, counts - K / 2.0, 0.0)
    total = jnp.sum(summand, axis=0)
    out = med - s * total / (m * psi_sum(K))
    out = jnp.where(s <= eps, med, out)
    o_ref[...] = out.astype(o_ref.dtype)


def _pad_rows(x, m_pad):
    m = x.shape[0]
    if m_pad == m:
        return x
    pad = jnp.full((m_pad - m,) + x.shape[1:], jnp.inf, dtype=x.dtype)
    return jnp.concatenate([x, pad], axis=0)


@functools.partial(
    jax.jit, static_argnames=("K", "vr", "tile", "interpret", "eps")
)
def _vrmom_2d(x, K: int, vr: bool, tile: int, interpret: bool, eps: float):
    m, c = x.shape
    m_pad = m + (m % 2)  # sorting network wants an even row count
    tile = min(tile, max(c, 1))
    c_pad = -(-c // tile) * tile
    xp = _pad_rows(x, m_pad)
    if c_pad != c:
        xp = jnp.pad(xp, ((0, 0), (0, c_pad - c)), constant_values=1.0)
    out = pl.pallas_call(
        functools.partial(_kernel, m=m, m_pad=m_pad, K=K, vr=vr, eps=eps),
        grid=(c_pad // tile,),
        in_specs=[pl.BlockSpec((m_pad, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((c_pad,), x.dtype),
        interpret=interpret,
    )(xp)
    return out[:c]


def _default_interpret():
    return jax.default_backend() != "tpu"


def vrmom_pallas(x, K: int = 10, tile: int = DEFAULT_TILE, interpret=None,
                 eps: float = 1e-12):
    """Fused VRMOM over axis 0. x: [m, ...] -> [...]. MAD scale."""
    if interpret is None:
        interpret = _default_interpret()
    shape = x.shape[1:]
    x2 = x.reshape(x.shape[0], -1)
    out = _vrmom_2d(x2, K=K, vr=True, tile=tile, interpret=bool(interpret),
                    eps=eps)
    return out.reshape(shape)


def mom_pallas(x, tile: int = DEFAULT_TILE, interpret=None):
    """Fused coordinate-wise median over axis 0."""
    if interpret is None:
        interpret = _default_interpret()
    shape = x.shape[1:]
    x2 = x.reshape(x.shape[0], -1)
    out = _vrmom_2d(x2, K=1, vr=False, tile=tile, interpret=bool(interpret),
                    eps=1e-12)
    return out.reshape(shape)
