"""Public jit'd entry points for the aggregation kernels.

``use_pallas=False`` falls back to the pure-jnp reference (used inside
shard_map on sub-tile chunks, and on backends without Pallas support).
On CPU the Pallas path runs in interpret mode automatically.
"""
from __future__ import annotations

from . import ref
from .vrmom import mom_pallas, vrmom_pallas

__all__ = ["robust_aggregate", "vrmom_pallas", "mom_pallas"]


def robust_aggregate(x, method: str = "vrmom", K: int = 10,
                     use_pallas: bool = True, interpret=None):
    """Aggregate [m, ...] -> [...] with the fused kernel or the oracle."""
    if method == "vrmom":
        if use_pallas:
            return vrmom_pallas(x, K=K, interpret=interpret)
        shape = x.shape[1:]
        return ref.ref_vrmom(x.reshape(x.shape[0], -1), K=K).reshape(shape)
    if method in ("mom", "median"):
        if use_pallas:
            return mom_pallas(x, interpret=interpret)
        shape = x.shape[1:]
        return ref.ref_mom(x.reshape(x.shape[0], -1)).reshape(shape)
    raise ValueError(f"unknown method {method!r}")
