"""Pure-jnp oracles for the aggregation kernels.

These are the reference semantics the Pallas kernels must match:
coordinate-wise mean / MOM / trimmed mean / VRMOM over the leading
(worker) axis with the MAD-based scale (DESIGN.md §2). Median over an
even worker count is the average of the two middle order statistics
(numpy convention). Dispatch policy lives in
``core.estimator.Estimator``; these are execution entry points.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.vrmom import _MAD_CONST, deltas, psi_sum


def ref_mean(x):
    """x: [M, C] -> [C] coordinate-wise mean (f32 accumulation)."""
    return jnp.mean(x.astype(jnp.float32), axis=0).astype(x.dtype)


def ref_mom(x):
    """x: [M, C] -> [C] coordinate-wise median."""
    return jnp.median(x.astype(jnp.float32), axis=0).astype(x.dtype)


def ref_trimmed_mean(x, beta: float = 0.1):
    """x: [M, C] -> [C] coordinate-wise beta-trimmed mean.

    Trims ``int(beta*M)`` order statistics at each end — the caller
    (``Estimator.validate``) guarantees the trim count is non-zero.
    """
    m = x.shape[0]
    k = int(beta * m)
    xs = jnp.sort(x.astype(jnp.float32), axis=0)
    return jnp.mean(xs[k : m - k if m - k > k else k + 1], axis=0).astype(
        x.dtype)


def ref_vrmom(x, K: int = 10, eps: float = 1e-12):
    """x: [M, C] -> [C] VRMOM (eq. 7) with MAD scale.

    Quantile counts accumulate k-at-a-time (K passes over [M, C]) so the
    [M, C, K] broadcast the naive expression materializes never exists —
    same trick as the fused kernel, and what makes this the fast jnp
    path for the serving-scale [m, B*V] stacks.
    """
    xf = x.astype(jnp.float32)
    M = xf.shape[0]
    med = jnp.median(xf, axis=0)
    mad = jnp.median(jnp.abs(xf - med[None, :]), axis=0)
    s = mad / _MAD_CONST
    z = (xf - med[None, :]) / jnp.maximum(s, eps)[None, :]
    d = deltas(K, dtype=jnp.float32)
    counts = jnp.zeros_like(z)
    for k in range(K):
        counts = counts + (z <= d[k]).astype(jnp.float32)
    total = jnp.sum(counts - K / 2.0, axis=0)
    out = med - s * total / (M * psi_sum(K))
    return jnp.where(s <= eps, med, out).astype(x.dtype)


def ref_attention(q, k, v, causal: bool = True):
    """Plain softmax attention oracle. q: [B,S,H,dh], k/v: [B,T,H,dh]."""
    B, S, H, dh = q.shape
    T = k.shape[1]
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (dh ** 0.5)
    if causal:
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
