"""Pure-jnp oracles for the aggregation kernels.

These are the reference semantics the Pallas kernels must match:
coordinate-wise MOM / VRMOM over the leading (worker) axis with the
MAD-based scale (DESIGN.md §2). Median over an even worker count is the
average of the two middle order statistics (numpy convention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.vrmom import deltas, psi_sum

_MAD_CONST = 0.6744897501960817  # ndtri(0.75)


def ref_mom(x):
    """x: [M, C] -> [C] coordinate-wise median."""
    return jnp.median(x.astype(jnp.float32), axis=0).astype(x.dtype)


def ref_vrmom(x, K: int = 10, eps: float = 1e-12):
    """x: [M, C] -> [C] VRMOM (eq. 7) with MAD scale."""
    xf = x.astype(jnp.float32)
    M = xf.shape[0]
    med = jnp.median(xf, axis=0)
    mad = jnp.median(jnp.abs(xf - med[None, :]), axis=0)
    s = mad / _MAD_CONST
    z = (xf - med[None, :]) / jnp.maximum(s, eps)[None, :]
    d = deltas(K, dtype=jnp.float32)
    counts = jnp.sum(z[..., None] <= d, axis=-1).astype(jnp.float32)
    total = jnp.sum(counts - K / 2.0, axis=0)
    out = med - s * total / (M * psi_sum(K))
    return jnp.where(s <= eps, med, out).astype(x.dtype)


def ref_attention(q, k, v, causal: bool = True):
    """Plain softmax attention oracle. q: [B,S,H,dh], k/v: [B,T,H,dh]."""
    B, S, H, dh = q.shape
    T = k.shape[1]
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (dh ** 0.5)
    if causal:
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
