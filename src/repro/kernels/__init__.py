"""Pallas TPU kernels for the paper's aggregation hot-spot."""
from . import ops, ref
from .ops import robust_aggregate
from .vrmom import mom_pallas, vrmom_pallas
