"""Pallas TPU kernels for the paper's aggregation hot-spot.

Execution entry points only — dispatch policy (method/backend selection)
is ``repro.core.estimator.Estimator``, the single aggregation dispatch
site (DESIGN.md §7).
"""
from . import ref
from .vrmom import (aggregate_pallas, mean_pallas, mom_pallas,
                    trimmed_mean_pallas, vrmom_pallas)
