"""Pallas TPU kernels: robust aggregation + attention.

Execution entry points only — dispatch policy lives one layer up:
``repro.core.estimator.Estimator`` for aggregation (DESIGN.md §7) and
``repro.models.attn_backend`` for attention (DESIGN.md §8).
"""
from . import ref
from .decode_attention import decode_attention
from .flash_attention import flash_attention
from .vrmom import (aggregate_pallas, mean_pallas, mom_pallas,
                    trimmed_mean_pallas, vrmom_pallas)
