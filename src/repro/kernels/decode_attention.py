"""Fused single-query (decode) attention Pallas kernel, GQA-grouped.

The serving hot loop is one query token per sequence attending over the
KV cache. The chunked jnp ``mha`` pays two avoidable memory costs per
decode step: a ``jnp.repeat`` of K/V from Hkv to H heads (4x cache read
traffic at the 4:1 GQA ratios of the assigned archs) and a materialized
f32 ``[B, H, 1, T]`` score tensor. This kernel does neither: GQA is
computed *grouped* — each K/V block is loaded into VMEM once per kv
head and shared by the whole [G = H/Hkv, dh] query group — and the
online-softmax state (m, l, acc) lives in VMEM scratch, so scores never
touch HBM.

Two layouts of the same online-softmax math (DESIGN.md §8):

* **narrow** (compiled TPU): grid ``(B, Hkv, n_kv_blocks)``, kv axis
  innermost (sequential, accumulating into scratch; the output block is
  written on the last kv step). Blocks are 2-D MXU-shaped: q ``[G,
  dh]``, K/V ``[blk_k, dh]``. K/V are viewed as ``[B, T, Hkv*dh]`` — a
  free reshape of the serving cache layout ``[B, T, Hkv, dh]`` — so the
  per-kv-head slab is a plain block of the last two dims (lane-aligned
  for dh in {64, 128}) with no transpose of the cache.
* **wide** (interpret mode, host CPU): grid ``(n_batch_blocks,
  n_kv_blocks)`` — kv innermost — with a ``[blk_b, Hkv, G, dh]`` query
  block and ``[blk_b, blk_k, Hkv*dh]`` K/V blocks resident at once,
  grouped einsums over the head axes. ``blk_b`` defaults to the whole
  batch (one batch block): per-grid-step interpreter overhead
  dominates, so one step per ``INTERPRET_BLK_K`` keys amortizes it (à
  la ``vrmom.INTERPRET_TILE``), which is what lets the kernel beat the
  chunked jnp ``mha`` at serving shapes on host CPU too
  (``BENCH_attn.json``).

int8 KV caches pass per-(row, position) ``[B, T]`` f32 scales; both
layouts fuse the dequant multiply into the K/V block load (the cache
crosses HBM at 1 byte/element — DESIGN.md §12).

Validity masking is per row: ``kv_len`` may be a scalar (classic batched
decode) or a per-row ``[B]`` vector (the slot-cache serving path,
DESIGN.md §6, where every slot sits at its own fill level). The
ring-buffer window cache needs no extra support: decode-with-window
masks by validity only (``kv_len = min(pos+1, T)``, slot order is
irrelevant to softmax — DESIGN.md §6), and tile padding beyond T rides
the same mask. Dispatch policy (which model layers run this vs the
chunked jnp ``mha``) lives in ``models/attn_backend.py``; this module is
the execution entry point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

DEFAULT_BLK_K = 256     # compiled TPU path: [blk_k, dh] K/V blocks in VMEM
INTERPRET_BLK_K = 4096  # interpret mode: amortize per-grid-step overhead

__all__ = ["decode_attention", "DEFAULT_BLK_K", "INTERPRET_BLK_K"]


def _online_update(s, pv, m_scr, l_scr, acc_scr):
    """One online-softmax accumulation step, shape-generic.

    s: scores [..., blk_k]; ``pv(p)`` contracts the probabilities with
    the value block to acc's shape [..., dh]. Scratch m/l are [...],
    acc is [..., dh].
    """
    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m_prev - m_new)
    m_scr[...] = m_new
    l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[..., None] + pv(p)


def _kernel_narrow(len_ref, q_ref, k_ref, v_ref, *refs, scale, blk_k, n_k,
                   has_scale):
    if has_scale:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = refs
    else:
        o_ref, m_scr, l_scr, acc_scr = refs
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # [G, dh] — the whole query group
    k = k_ref[0].astype(jnp.float32)     # [blk_k, dh] — loaded ONCE per
    v = v_ref[0].astype(jnp.float32)     # kv head, shared by all G rows
    if has_scale:
        # int8 KV: per-position dequant fused into the block load
        # (DESIGN.md §12) — the cache crosses HBM at 1 byte/element
        k = k * ks_ref[0][:, None]
        v = v * vs_ref[0][:, None]
    s = jnp.dot(q * scale, k.T, preferred_element_type=jnp.float32)

    kv_len = len_ref[0, 0]
    k_pos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < kv_len, s, NEG_INF)
    _online_update(
        s, lambda p: jnp.dot(p, v, preferred_element_type=jnp.float32),
        m_scr, l_scr, acc_scr)

    @pl.when(ki == n_k - 1)
    def _done():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


def _kernel_wide(len_ref, q_ref, k_ref, v_ref, *refs, scale, blk_k, n_k,
                 has_scale):
    if has_scale:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = refs
    else:
        o_ref, m_scr, l_scr, acc_scr = refs
    ki = pl.program_id(1)  # kv axis innermost; batch blocks outer

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    B, Hkv, G, dh = q_ref.shape  # B here is the batch block (blk_b rows)
    q = q_ref[...].astype(jnp.float32)                       # [B,Hkv,G,dh]
    k = k_ref[...].astype(jnp.float32).reshape(B, blk_k, Hkv, dh)
    v = v_ref[...].astype(jnp.float32).reshape(B, blk_k, Hkv, dh)
    if has_scale:
        # int8 KV: per-(row, position) dequant fused into the block load
        k = k * ks_ref[...][:, :, None, None]
        v = v * vs_ref[...][:, :, None, None]
    s = jnp.einsum("bhgd,bthd->bhgt", q * scale, k,
                   preferred_element_type=jnp.float32)       # [B,Hkv,G,blk]

    kv_len = len_ref[...]                                    # [B, 1]
    k_pos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 3)
    s = jnp.where(k_pos < kv_len[:, 0][:, None, None, None], s, NEG_INF)
    _online_update(
        s, lambda p: jnp.einsum("bhgt,bthd->bhgd", p, v,
                                preferred_element_type=jnp.float32),
        m_scr, l_scr, acc_scr)

    @pl.when(ki == n_k - 1)
    def _done():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("blk_k", "blk_b", "interpret"))
def _decode_grouped(q, k, v, lens, k_scale, v_scale, blk_k, blk_b,
                    interpret):
    """q: [B, Hkv, G, dh]; k/v: [B, T, Hkv, dh]; lens: [B] int32;
    k_scale/v_scale: [B, T] f32 int8-dequant scales or None."""
    B, Hkv, G, dh = q.shape
    T = k.shape[1]
    has_scale = k_scale is not None
    blk_k = min(blk_k, T)
    pad_k = (-T) % blk_k
    if pad_k:
        # padded slots fall beyond kv_len <= T: masked out in-kernel
        padw = ((0, 0), (0, pad_k), (0, 0), (0, 0))
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
        if has_scale:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad_k)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad_k)))
    Tk = T + pad_k
    n_k = Tk // blk_k
    # Free reshape: the per-kv-head [blk_k, dh] slab becomes a plain
    # block of the last two dims — the cache is never transposed.
    k2 = k.reshape(B, Tk, Hkv * dh)
    v2 = v.reshape(B, Tk, Hkv * dh)
    scale = 1.0 / (dh ** 0.5)

    if interpret:
        # wide layout: [blk_b, Hkv, G, dh] query block per grid step,
        # batch blocks outer, kv axis inner (scratch accumulates per
        # batch block). Zero-padded batch rows (lens 0) normalize to 0
        # and are sliced off below.
        blk_b = min(blk_b, B)
        pad_b = (-B) % blk_b
        if pad_b:
            q = jnp.pad(q, ((0, pad_b),) + ((0, 0),) * 3)
            k2 = jnp.pad(k2, ((0, pad_b), (0, 0), (0, 0)))
            v2 = jnp.pad(v2, ((0, pad_b), (0, 0), (0, 0)))
            lens = jnp.pad(lens, (0, pad_b))
            if has_scale:
                k_scale = jnp.pad(k_scale, ((0, pad_b), (0, 0)))
                v_scale = jnp.pad(v_scale, ((0, pad_b), (0, 0)))
        Bb = B + pad_b
        kernel = functools.partial(_kernel_wide, scale=scale, blk_k=blk_k,
                                   n_k=n_k, has_scale=has_scale)
        grid = (Bb // blk_b, n_k)
        in_specs = [
            pl.BlockSpec((blk_b, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((blk_b, Hkv, G, dh), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((blk_b, blk_k, Hkv * dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((blk_b, blk_k, Hkv * dh), lambda i, j: (i, j, 0)),
        ]
        if has_scale:
            in_specs += [pl.BlockSpec((blk_b, blk_k), lambda i, j: (i, j)),
                         pl.BlockSpec((blk_b, blk_k), lambda i, j: (i, j))]
        out_specs = pl.BlockSpec((blk_b, Hkv, G, dh),
                                 lambda i, j: (i, 0, 0, 0))
        out_shape = jax.ShapeDtypeStruct((Bb, Hkv, G, dh), q.dtype)
        scratch = [
            pltpu.VMEM((blk_b, Hkv, G), jnp.float32),
            pltpu.VMEM((blk_b, Hkv, G), jnp.float32),
            pltpu.VMEM((blk_b, Hkv, G, dh), jnp.float32),
        ]
    else:
        # narrow layout: 2-D MXU-shaped blocks, kv axis sequential; the
        # batch already rides the grid row-by-row (blk_b inapplicable)
        Bb = B
        kernel = functools.partial(_kernel_narrow, scale=scale, blk_k=blk_k,
                                   n_k=n_k, has_scale=has_scale)
        grid = (B, Hkv, n_k)
        in_specs = [
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, 1, G, dh), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda b, h, j: (b, j, h)),
            pl.BlockSpec((1, blk_k, dh), lambda b, h, j: (b, j, h)),
        ]
        if has_scale:
            in_specs += [pl.BlockSpec((1, blk_k), lambda b, h, j: (b, j)),
                         pl.BlockSpec((1, blk_k), lambda b, h, j: (b, j))]
        out_specs = pl.BlockSpec((1, 1, G, dh), lambda b, h, j: (b, h, 0, 0))
        out_shape = jax.ShapeDtypeStruct((B, Hkv, G, dh), q.dtype)
        scratch = [
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, dh), jnp.float32),
        ]

    args = (lens[:, None], q, k2, v2)
    if has_scale:
        args += (k_scale, v_scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(*args)
    return out[:B] if Bb != B else out


def _default_interpret():
    return jax.default_backend() != "tpu"


def decode_attention(q, k, v, *, kv_len=None, blk_k=None, blk_b=None,
                     interpret=None, k_scale=None, v_scale=None):
    """Fused single-query attention over a KV cache.

    q: [B, 1, H, dh]; k/v: [B, T, Hkv, dh] with H divisible by Hkv
    (grouped in-kernel — K/V are never repeated to H). ``kv_len``:
    valid cache length — None (whole cache), a scalar, or a per-row [B]
    vector (slot-cache serving). Returns [B, 1, H, dh] in q's dtype
    (f32 softmax/accumulation internally).

    ``blk_k=None`` picks the kv tile per mode: a VMEM-sized block when
    compiled, a wide block when interpreted (per-grid-step interpreter
    overhead dominates otherwise — ``BENCH_attn.json``). ``blk_b``
    tiles the *batch* axis of the wide layout (None -> whole batch
    resident per grid step — at serving batches the extra grid steps
    cost more interpreter overhead than the smaller block saves; the
    narrow layout already walks the batch on its grid).

    ``k_scale``/``v_scale``: per-(row, position) [B, T] f32 dequant
    scales of an int8 cache; the dequant multiply is fused into the
    K/V block loads so the cache crosses HBM at 1 byte/element
    (DESIGN.md §12).
    """
    if interpret is None:
        interpret = _default_interpret()
    if blk_k is None:
        blk_k = INTERPRET_BLK_K if interpret else DEFAULT_BLK_K
    B, S, H, dh = q.shape
    if S != 1:
        raise ValueError(f"decode_attention is single-query; got S={S}")
    T, Hkv = k.shape[1], k.shape[2]
    if H % Hkv:
        raise ValueError(f"H={H} not divisible by Hkv={Hkv}")
    if (k_scale is None) != (v_scale is None):
        raise ValueError("k_scale and v_scale must be passed together")
    G = H // Hkv
    # query head h belongs to kv head h // G — the same grouping
    # jnp.repeat(k, G, axis=2) realizes — so the reshape is exact.
    qg = q[:, 0].reshape(B, Hkv, G, dh)
    if kv_len is None:
        lens = jnp.full((B,), T, jnp.int32)
    else:
        kv_len = jnp.asarray(kv_len, jnp.int32)
        lens = jnp.broadcast_to(kv_len, (B,))
    lens = jnp.minimum(lens, T)
    if k_scale is not None:
        k_scale = jnp.broadcast_to(
            jnp.asarray(k_scale, jnp.float32), (B, T))
        v_scale = jnp.broadcast_to(
            jnp.asarray(v_scale, jnp.float32), (B, T))
    from ..obs.trace import named_span

    with named_span("kernels.decode_attention"):
        out = _decode_grouped(qg, k, v, lens, k_scale, v_scale,
                              int(blk_k), int(blk_b or B),
                              bool(interpret))
    return out.reshape(B, 1, H, dh)
