"""Pure-JAX optimizers: SGD(+momentum), AdamW, Adafactor.

Each optimizer is an ``Optimizer(init, update)`` pair of pure functions
— ``init(params) -> state``, ``update(grads, state, params) ->
(new_params, new_state)`` — selected by name via :func:`get` (the
``ArchConfig.optimizer`` field). States are plain dicts of pytrees that
mirror the params tree, so sharding specs derive mechanically from the
param specs (``repro.dist.sharding.opt_state_specs``: adafactor's
factored ``vr``/``vc`` leaves get the row/column slices of the param
spec, everything else mirrors by shape). Updates are computed in f32
regardless of param dtype and cast back on write.

Adafactor exists because f32 Adam moments for llama3-405b exceed v5e
HBM (DESIGN.md §5): a factored second moment (one row + one column
vector per matrix, Shazeer & Stern 2018) plus a bf16 first moment
brings optimizer state to ~3 GB/chip on the production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Any   # params -> state
    update: Any  # (grads, state, params) -> (new_params, new_state)


def _tmap(f, *trees):
    return jax.tree.map(f, *trees)


def sgd(lr: float = 1e-2, momentum: float = 0.0):
    def init(params):
        if momentum == 0.0:
            return {"step": jnp.zeros((), jnp.int32)}
        return {"m": _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        if momentum == 0.0:
            new_p = _tmap(lambda p, g: (p.astype(jnp.float32)
                                        - lr * g.astype(jnp.float32)
                                        ).astype(p.dtype), params, grads)
            return new_p, {"step": state["step"] + 1}
        m = _tmap(lambda m, g: momentum * m + g.astype(jnp.float32),
                  state["m"], grads)
        new_p = _tmap(lambda p, mm: (p.astype(jnp.float32) - lr * mm
                                     ).astype(p.dtype), params, m)
        return new_p, {"m": m, "step": state["step"] + 1}

    return Optimizer(init, update)


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0):
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": _tmap(z, params), "v": _tmap(z, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        flat_p, treedef = jax.tree.flatten(params)
        outs = [upd(p, g, m, v) for p, g, m, v in zip(
            flat_p, jax.tree.leaves(grads),
            treedef.flatten_up_to(state["m"]),
            treedef.flatten_up_to(state["v"]))]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
        m = jax.tree.unflatten(treedef, [o[1] for o in outs])
        v = jax.tree.unflatten(treedef, [o[2] for o in outs])
        return new_p, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


def adafactor(lr: float = 1e-3, eps: float = 1e-30, momentum: float = 0.9,
              momentum_dtype=jnp.bfloat16, clip_rms: float = 1.0,
              decay: float = 0.8):
    """Factored second moment (Shazeer & Stern 2018), bf16 first moment."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def vstate(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}

        st = {"v": jax.tree.map(vstate, params),
              "step": jnp.zeros((), jnp.int32)}
        if momentum:
            st["m"] = _tmap(lambda p: jnp.zeros_like(p, momentum_dtype), params)
        return st

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-decay)

        def upd(p, g, vs, m):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta2 * vs["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * vs["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                vhat = vr[..., None] * vc[..., None, :] / denom[..., None]
                new_vs = {"vr": vr, "vc": vc}
            else:
                vhat = beta2 * vs["v"] + (1 - beta2) * g2
                new_vs = {"v": vhat}
            u = g / jnp.sqrt(vhat + eps)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_rms)
            if m is not None:
                mf = momentum * m.astype(jnp.float32) + (1 - momentum) * u
                u = mf
                new_m = mf.astype(momentum_dtype)
            else:
                new_m = None
            new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            return new_p, new_vs, new_m

        is_v = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        flat_m = (treedef.flatten_up_to(state["m"]) if momentum
                  else [None] * len(flat_p))
        outs = [upd(p, g, v, m) for p, g, v, m in
                zip(flat_p, flat_g, flat_v, flat_m)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_v = jax.tree.unflatten(treedef, [o[1] for o in outs])
        st = {"v": new_v, "step": step}
        if momentum:
            st["m"] = jax.tree.unflatten(treedef, [o[2] for o in outs])
        return new_p, st

    return Optimizer(init, update)


def get(name: str, **kwargs) -> Optimizer:
    return {"sgd": sgd, "adamw": adamw, "adafactor": adafactor}[name](**kwargs)
