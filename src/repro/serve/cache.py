"""Slot-based KV cache pool for continuous batching (DESIGN.md §6).

The pool decouples cache capacity from the request batch: it holds
``n_slots`` cache rows (one per concurrently-decoding sequence), each
with its own fill level. Requests are admitted into free slots
mid-decode and retired slots are reused without touching the others.

Per-slot positions ride inside the model cache tree itself: every
``attention.KVCache.pos`` leaf is *vectorized* from a per-layer scalar
to a per-layer ``[n_slots]`` vector (``vectorize_pos``), which the
generalized ``attn_decode`` consumes row-wise. SSM caches are
positionless state and need no conversion.

Batch-dim discovery is structural, not name-based: the pool constructor
is probed with ``eval_shape`` at two slot counts and the dim that
changes is the slot dim (``slot_dims``). This keeps the pool agnostic to
cache layouts — transformer ``[L, B, T, H, dh]``, hybrid grouped
``[G, every, B, ...]``, whisper cross ``[L, B, F, H, dh]``, and the
replica-stacked trees of the robust path ``[m, L, B, ...]`` all work
through the same code.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..models import attention as A
from ..models import model as M

__all__ = [
    "SlotPool",
    "vectorize_pos",
    "slot_dims",
    "kv_bytes_per_slot",
    "init_pool",
    "write_slot",
    "evict_slot",
    "pool_specs",
]

_NO_SLOT_DIM = -1  # sentinel: leaf has no slot dim (replicated metadata)


class SlotPool(NamedTuple):
    """Cache pool: model caches + per-slot bookkeeping.

    caches:  model cache pytree with a slot dim per leaf (possibly
             replica-stacked by the robust path).
    lengths: [n_slots] int32 — tokens resident per slot (prompt + generated).
    active:  [n_slots] bool — slot currently owned by a live request.
    """

    caches: Any
    lengths: jnp.ndarray
    active: jnp.ndarray

    @property
    def n_slots(self) -> int:
        return self.lengths.shape[0]


def vectorize_pos(caches, n_slots: int):
    """Broadcast every KVCache.pos leaf to a trailing per-slot dim.

    [L]-shaped per-layer scalars become [L, n_slots]; the generalized
    ``attn_decode`` then advances each row independently.
    """
    def conv(c):
        if isinstance(c, A.KVCache):
            pos = jnp.broadcast_to(
                c.pos[..., None], c.pos.shape + (n_slots,)).astype(jnp.int32)
            return c._replace(pos=pos)
        return c

    return jax.tree.map(conv, caches,
                        is_leaf=lambda x: isinstance(x, A.KVCache))


def _pool_caches(cfg, n_slots: int, max_len: int, window="cfg"):
    return vectorize_pos(M.init_cache(cfg, n_slots, max_len, window=window),
                         n_slots)


def slot_dims(make, n_a: int = 2, n_b: int = 3):
    """Per-leaf slot-dim index for the cache tree built by ``make(n_slots)``.

    Probes ``make`` at two slot counts under ``eval_shape`` (no
    allocation) and returns, per leaf, the index of the dim whose size
    tracked the slot count, or ``_NO_SLOT_DIM`` for slot-free leaves
    (e.g. SSM layer-position metadata).
    """
    sa = jax.eval_shape(lambda: make(n_a))
    sb = jax.eval_shape(lambda: make(n_b))

    def one(x, y):
        diffs = [i for i, (p, q) in enumerate(zip(x.shape, y.shape)) if p != q]
        return diffs[0] if diffs else _NO_SLOT_DIM

    return jax.tree.map(one, sa, sb)


def kv_bytes_per_slot(make, n_slots: int) -> int:
    """HBM bytes one slot costs in the cache tree built by ``make``.

    Probed under ``eval_shape`` (no allocation): sum of leaf byte sizes
    — int8 quantization scales included, which is the point: the gauge
    reports the *stored* footprint, so ``kv_dtype`` shrinking the cache
    shows up directly. Replica-stacked robust trees count every
    replica's bytes (they all occupy HBM per slot).
    """
    tree = jax.eval_shape(lambda: make(n_slots))
    total = sum(int(x.size) * x.dtype.itemsize
                for x in jax.tree.leaves(tree))
    return total // n_slots


def init_pool(cfg, n_slots: int, max_len: int, window="cfg") -> SlotPool:
    """Empty pool: zeroed caches, zero lengths, all slots free."""
    return SlotPool(
        caches=_pool_caches(cfg, n_slots, max_len, window=window),
        lengths=jnp.zeros((n_slots,), jnp.int32),
        active=jnp.zeros((n_slots,), bool),
    )


def write_slot(pool: SlotPool, dims, req_caches, slot, length) -> SlotPool:
    """Admit one request: insert its (batch-1) cache row at ``slot``.

    ``dims`` is the ``slot_dims`` tree for ``pool.caches``;
    ``req_caches`` must match ``pool.caches`` structurally with slot-dim
    size 1 (vectorize + replica-stack first — the engine does this).
    """
    slot = jnp.asarray(slot, jnp.int32)

    def one(dst, d, src):
        if d == _NO_SLOT_DIM:
            return dst
        return jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), slot, axis=d)

    caches = jax.tree.map(one, pool.caches, dims, req_caches)
    return SlotPool(
        caches=caches,
        lengths=pool.lengths.at[slot].set(jnp.asarray(length, jnp.int32)),
        active=pool.active.at[slot].set(True),
    )


def evict_slot(pool: SlotPool, slot) -> SlotPool:
    """Retire a slot. Cache contents stay (masked by per-slot lengths and
    overwritten on the next admit); only the bookkeeping is cleared."""
    slot = jnp.asarray(slot, jnp.int32)
    return SlotPool(
        caches=pool.caches,
        lengths=pool.lengths.at[slot].set(0),
        active=pool.active.at[slot].set(False),
    )


def pool_specs(cfg, pool: SlotPool, mesh, batch_axes):
    """PartitionSpec tree for a pool: caches via ``sharding.cache_specs``
    (slot dim plays the batch role), bookkeeping replicated."""
    from jax.sharding import PartitionSpec as P

    from ..dist import sharding as S

    cache_shapes = jax.eval_shape(lambda: pool.caches)
    cspecs = S.cache_specs(cfg, cache_shapes, mesh, batch_axes,
                           global_batch=pool.n_slots)
    return SlotPool(caches=cspecs, lengths=P(None), active=P(None))
