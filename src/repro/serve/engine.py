"""Prefill-then-decode serving engine (DESIGN.md §6).

Two entry styles over the same jitted step functions:

* fixed-batch ``generate`` — prefill a [B, S] prompt batch, then decode
  N tokens in ONE ``lax.scan`` dispatch (the per-step Python loop of the
  old example dispatched the jitted step N times from the host; the scan
  removes that per-token host round-trip and lets XLA pipeline the
  steps).
* slot-pool ``admit`` / ``decode_pool`` — the continuous-batching path:
  variable-length prompts prefill one request at a time into a free slot
  of a ``cache.SlotPool`` while the other slots keep decoding; the
  scheduler drives the admit/decode/retire cycle.

Sampling (greedy, temperature, top-k) is folded into the scanned loop so
sampled decode is a single dispatch too. With a ``RobustDecodeConfig``
every decode step runs replicated over ``m`` replicas and serves the
robustly aggregated logits (``serve.robust``).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..models import model as M
from . import cache as C
from . import robust as R

__all__ = ["Sampling", "GREEDY", "sample_tokens", "ServeEngine"]


class Sampling(NamedTuple):
    """Static sampling config (hashable — part of the jit cache key).

    method: 'greedy' | 'temperature' | 'top_k'
    """

    method: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0


GREEDY = Sampling()


def sample_tokens(logits, key, sc: Sampling):
    """logits [..., V] -> sampled token ids [...] int32."""
    if sc.method == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = logits.astype(jnp.float32) / max(sc.temperature, 1e-6)
    if sc.method == "top_k":
        if sc.top_k <= 0:
            raise ValueError("top_k sampling needs top_k > 0")
        kth = jax.lax.top_k(l, sc.top_k)[0][..., -1:]
        l = jnp.where(l < kth, -jnp.inf, l)
    elif sc.method != "temperature":
        raise ValueError(sc.method)
    return jax.random.categorical(key, l, axis=-1).astype(jnp.int32)


class ServeEngine:
    """Holds (cfg, params, pool geometry) and a cache of jitted steps.

    max_len:  KV capacity per slot (prompt + generated must fit).
    n_slots:  pool capacity — concurrent sequences, decoupled from the
              number of queued requests.
    robust:   optional ``RobustDecodeConfig`` — decode replicated over
              ``robust.m`` replicas with robust logit aggregation.
    attn_backend: optional override of ``cfg.attn_backend`` (DESIGN.md
              §8) — carried on the config, so every jitted step
              (prefill, scanned decode, the replica-flat robust loop)
              inherits it and the fused decode-attention kernel runs
              inside the same scan as the fused aggregation kernel.
    obs:      optional ``obs.MetricsRegistry``. With a robust config the
              scanned decode loop additionally collects the per-token
              replica-disagreement rate as a fixed-edge histogram-counts
              aux (``obs.diag.ServeDiag`` — static shape, no host
              callbacks in the scan) drained into the registry's
              ``serve.replica_disagreement`` histogram after each
              dispatch. The diag flag joins the jit cache key, so the
              telemetry-free loop is a distinct compiled program whose
              tokens stay bit-identical to ``obs=None``.
    """

    def __init__(self, cfg, params, *, max_len: int, n_slots: int = 4,
                 window="cfg", robust: Optional[R.RobustDecodeConfig] = None,
                 attn_backend: Optional[str] = None,
                 kv_dtype: Optional[str] = None, obs=None):
        if attn_backend is not None:
            import dataclasses

            from ..models.attn_backend import BACKENDS

            if attn_backend not in BACKENDS:
                raise ValueError(f"unknown attn backend {attn_backend!r}; "
                                 f"known: {BACKENDS}")
            cfg = dataclasses.replace(cfg, attn_backend=attn_backend)
        if kv_dtype is not None:
            import dataclasses

            from ..models.attention import KV_DTYPES

            if kv_dtype not in KV_DTYPES:
                raise ValueError(f"unknown kv dtype {kv_dtype!r}; "
                                 f"known: {KV_DTYPES}")
            cfg = dataclasses.replace(cfg, kv_dtype=kv_dtype)
        self.cfg = cfg
        self.params = params
        self.max_len = int(max_len)
        self.n_slots = int(n_slots)
        self.window = window
        self.robust = robust
        self.obs = obs
        # replicated emulation: replica state actually materialized
        # [m, ...] and every replica's forward executed. The default
        # (share_replica_compute) keeps plain-shaped state — one forward
        # feeds the whole logit stack (see RobustDecodeConfig).
        self._replicated = (robust is not None
                            and not robust.share_replica_compute)
        self._fns = {}
        self._dims = C.slot_dims(self._pool_caches)
        if obs is not None:
            # capacity gauge: KV bytes one slot costs (scales included,
            # and the m-fold replica stacking when the emulation
            # replicates state), from the abstract pool spec — no
            # allocation. Quantized KV shows up here as the
            # halved/quartered per-slot footprint.
            obs.gauge("serve.kv_bytes_per_slot",
                      float(C.kv_bytes_per_slot(self._pool_caches,
                                                self.n_slots)))
        if self._replicated:
            # batch-dim indices of the UNSTACKED pool tree: the replica
            # dim the probe saw at axis 0 shifts every slot dim by one.
            self._pool_flat_dims = jax.tree.map(
                lambda d: d - 1 if d >= 0 else d, self._dims)
        self._prefill_dims_cache = {}

    # -- pool construction --------------------------------------------------

    def _pool_caches(self, n_slots: int):
        caches = C._pool_caches(self.cfg, n_slots, self.max_len,
                                window=self.window)
        if self._replicated:
            caches = R.stack_replicas(caches, self.robust.m)
        return caches

    def make_pool(self) -> C.SlotPool:
        pool = C.init_pool(self.cfg, self.n_slots, self.max_len,
                           window=self.window)
        if self._replicated:
            pool = pool._replace(
                caches=R.stack_replicas(pool.caches, self.robust.m))
        return pool

    # -- jitted step functions (cached per static signature) ----------------

    def _fn(self, key, build):
        fn = self._fns.get(key)
        if fn is None:
            fn = self._fns[key] = build()
        return fn

    def _prefill_fn(self):
        def run(params, batch):
            logits, caches = M.prefill(params, self.cfg, batch,
                                       window=self.window,
                                       cache_len=self.max_len, last_only=True)
            return logits[:, -1], caches

        return self._fn("prefill", lambda: jax.jit(run))

    def _prefill_dims(self, batch):
        """Per-leaf batch-dim indices of the prefill cache tree.

        Structural, like ``cache.slot_dims``: the prefill constructor is
        probed under ``eval_shape`` at two batch sizes (abstract — no
        compute) and the dim that tracks the batch is the batch dim.
        Keyed by the batch's field set (encdec extras change the tree).
        """
        key = tuple(sorted(batch))
        dims = self._prefill_dims_cache.get(key)
        if dims is None:
            def make(n):
                b = {k: jnp.zeros((n,) + v.shape[1:], v.dtype)
                     for k, v in batch.items()}
                return M.prefill(self.params, self.cfg, b, window=self.window,
                                 cache_len=self.max_len, last_only=True)[1]

            dims = self._prefill_dims_cache[key] = C.slot_dims(make)
        return dims

    def _decode_loop_fn(self, n_steps: int, sc: Sampling, pool: bool,
                        donate: bool = False):
        """Fused decode: one dispatch for ``n_steps`` steps of
        decode -> (attack/aggregate) -> sample, caches carried in-scan.

        Robust decode with ``share_replica_compute`` (default) runs ONE
        ``decode_step`` per scan step and broadcasts its logits into the
        [m, B, V] wire stack (honest replicas are bit-identical — see
        RobustDecodeConfig); the replicated emulation instead runs
        replica-FLAT (``robust.flatten_replicas``): the m replicas ride
        the batch dim through one ``decode_step`` call at batch m*B, and
        the [m*B, V] logits reshape to the wire stack. Either way the
        fused Estimator kernel aggregates the stack in-scan.
        The pool path passes (and receives) the replica-STACKED layout —
        admit/evict write [m, ...] rows — and the layout round-trip
        happens inside the jitted program so XLA fuses it with the
        first/last cache accesses instead of materializing eager
        transpose copies of the whole pool per block. The generate path
        passes pre-flattened caches (its conversion is once per call).
        """
        rcfg = self.robust
        flat_dims = (self._pool_flat_dims
                     if pool and self._replicated else None)
        # Telemetry variant: a distinct compiled program (diag joins the
        # cache key) whose scan additionally emits the per-token replica-
        # disagreement rates, folded post-scan into a static-shape
        # fixed-edge counts vector (obs.diag.ServeDiag). Tokens are
        # computed identically — the diag aux reads the logit stack and
        # never feeds back.
        diag = self.obs is not None and rcfg is not None
        # Greedy sampling with no simulated attack consumes no
        # randomness — skip the per-step threefry split (a measurable
        # slice of the step on a host-bound box). Token-identical: the
        # skipped keys were never read.
        stochastic = sc.method != "greedy" or (
            rcfg is not None and rcfg.attack != "none")

        def run(params, caches, tok, key, active=None):
            # active: optional [B] bool — pool-path slot liveness. Only
            # the diag aux reads it (inactive slots decode stale caches;
            # their disagreement rates are masked out of the histogram);
            # tokens and caches are computed identically either way.
            if flat_dims is not None:
                caches = R.flatten_replicas(caches, flat_dims, rcfg.m)

            def body(carry, _):
                tok, caches, key = carry
                if stochastic:
                    key, akey, skey = jax.random.split(key, 3)
                else:
                    akey = skey = key
                dis = None
                if rcfg is not None:
                    if rcfg.share_replica_compute:
                        # one forward feeds the whole wire stack — the
                        # replicas are bit-identical deterministic
                        # functions of the same carry (config docstring)
                        logits, caches = M.decode_step(params, self.cfg,
                                                       caches, tok,
                                                       window=self.window)
                        logits_r = jnp.broadcast_to(
                            logits, (rcfg.m,) + logits.shape)
                    else:
                        flat_tok = jnp.tile(tok, rcfg.m)  # replica-major
                        logits_f, caches = M.decode_step(params, self.cfg,
                                                         caches, flat_tok,
                                                         window=self.window)
                        logits_r = logits_f.reshape((rcfg.m, tok.shape[0])
                                                    + logits_f.shape[1:])
                    # the whole tail — attack, aggregate, sample — is one
                    # fused dispatch when rcfg.fuse_tail (DESIGN.md §12)
                    if diag:
                        nxt, dis = R.robust_sample(logits_r, rcfg, akey,
                                                   skey, sc, with_diag=True)
                    else:
                        nxt = R.robust_sample(logits_r, rcfg, akey, skey, sc)
                else:
                    logits, caches = M.decode_step(params, self.cfg, caches,
                                                   tok, window=self.window)
                    nxt = sample_tokens(logits, skey, sc)
                return (nxt, caches, key), (nxt, dis) if diag else nxt

            from ..obs.trace import named_span

            with named_span("serve.decode_scan"):
                (tok, caches, _), ys = jax.lax.scan(
                    body, (tok, caches, key), None, length=n_steps)
            if flat_dims is not None:
                caches = R.unflatten_replicas(caches, flat_dims, rcfg.m)
            if diag:
                from ..obs.catalog import FRACTION_EDGES
                from ..obs.diag import serve_diag

                toks, dis = ys  # dis: [n_steps, B] disagreement rates
                mask = None if active is None else active[None, :]
                return toks, caches, serve_diag(dis, FRACTION_EDGES,
                                                mask=mask)
            return ys, caches  # ys: toks [n_steps, B]

        # donate=True hands the caches buffer to XLA so the scan carry
        # reuses it in place instead of copying ~MB of KV at entry.
        # Only the generate() path asks for it — its caches are freshly
        # built per call and never touched again; pool/benchmark callers
        # re-feed the same caches across calls, which donation forbids.
        return self._fn(("loop", n_steps, sc, pool, diag, donate),
                        lambda: jax.jit(
                            run, donate_argnums=(1,) if donate else ()))

    def _decode_step_fn(self, sc: Sampling):
        """Single-step dispatch — the Python-loop baseline the scan
        replaces (kept for benchmarks and debugging)."""
        rcfg = self.robust

        def run(params, caches, tok, key):
            akey, skey = jax.random.split(key)
            if rcfg is not None:
                logits, caches = R.robust_decode_step(
                    params, self.cfg, caches, tok, rcfg, akey,
                    window=self.window)
            else:
                logits, caches = M.decode_step(params, self.cfg, caches, tok,
                                               window=self.window)
            return sample_tokens(logits, skey, sc), caches

        return self._fn(("step", sc), lambda: jax.jit(run))

    def _drain_serve_diag(self, sd, n: int) -> None:
        """Fold a jit-side ``ServeDiag`` aux into the host registry:
        one device->host transfer of a fixed-size counts vector per
        dispatch (never per token)."""
        h = self.obs.histogram("serve.replica_disagreement")
        h.merge_counts([int(c) for c in sd.counts], float(sd.total), n)

    # -- fixed-batch generation ---------------------------------------------

    def prefill(self, batch):
        """-> (last-position logits [B, V], caches)."""
        return self._prefill_fn()(self.params, batch)

    def _check_capacity(self, prompt_len: int, n_tokens: int) -> None:
        # cache writes: prompt + one K/V per decode step (n_tokens - 1;
        # the first token samples off the prefill logits). Beyond
        # max_len the linear cache would silently clamp to its last
        # slot and corrupt attention.
        need = prompt_len + n_tokens - 1
        if need > self.max_len:
            raise ValueError(
                f"prompt {prompt_len} + {n_tokens} tokens needs {need} "
                f"cache slots > max_len {self.max_len}")

    def _first_token(self, logits, key, sc):
        """Sample token 0 from the prefill logits (jitted, cached).

        With a robust config the logits route through the same attack +
        aggregation as decode, so token 0 carries the robustness
        guarantee too: the prefill forward is deterministic, so
        row-stacking its logits is equivalent to re-running it on every
        replica.
        """
        rcfg = self.robust

        def run(logits, key):
            if rcfg is not None:
                rep = jnp.broadcast_to(logits[None],
                                       (rcfg.m,) + logits.shape)
                return R.robust_sample(rep, rcfg, jax.random.fold_in(key, 1),
                                       jax.random.fold_in(key, 0), sc)
            return sample_tokens(logits, jax.random.fold_in(key, 0), sc)

        return self._fn(("first", sc), lambda: jax.jit(run))(logits, key)

    def _stack_flatten_fn(self, batch):
        """Jitted prefill-cache -> replica-flat conversion (cached per
        batch structure: the dims tree keys the compiled program)."""
        dims = self._prefill_dims(batch)
        leaves, treedef = jax.tree.flatten(dims)
        m = self.robust.m

        def run(caches):
            return R.flatten_replicas(R.stack_replicas(caches, m), dims, m)

        return self._fn(("stack-flatten", tuple(leaves), treedef),
                        lambda: jax.jit(run))

    def generate(self, batch, n_tokens: int, sampling: Sampling = GREEDY,
                 key=None):
        """Prefill + scanned decode. -> tokens [B, n_tokens] int32."""
        self._check_capacity(batch["tokens"].shape[1], n_tokens)
        key = jax.random.PRNGKey(0) if key is None else key
        logits, caches = self.prefill(batch)
        tok = self._first_token(logits, key, sampling)
        if n_tokens == 1:
            return tok[:, None]
        if self._replicated:
            caches = self._stack_flatten_fn(batch)(caches)
        out = self._decode_loop_fn(n_tokens - 1, sampling, pool=False,
                                   donate=True)(
            self.params, caches, tok, key)
        toks = out[0]
        if len(out) == 3:
            self._drain_serve_diag(out[2], (n_tokens - 1) * tok.shape[0])
        return jnp.concatenate([tok[:, None], toks.T], axis=1)

    def generate_python_loop(self, batch, n_tokens: int,
                             sampling: Sampling = GREEDY, key=None):
        """Same semantics as ``generate`` but one host dispatch per token
        (the pre-engine decode loop) — the benchmark baseline."""
        self._check_capacity(batch["tokens"].shape[1], n_tokens)
        key = jax.random.PRNGKey(0) if key is None else key
        logits, caches = self.prefill(batch)
        if self._replicated:
            caches = R.stack_replicas(caches, self.robust.m)
        tok = self._first_token(logits, key, sampling)
        step = self._decode_step_fn(sampling)
        out = [tok]
        for i in range(n_tokens - 1):
            tok, caches = step(self.params, caches, tok,
                               jax.random.fold_in(key, i + 1))
            out.append(tok)
        return jnp.stack(out, axis=1)

    # -- slot-pool path (continuous batching) -------------------------------

    def admit(self, pool: C.SlotPool, slot: int, batch,
              sampling: Sampling = GREEDY, key=None):
        """Prefill one request (batch dim 1) into ``slot``.

        Runs while the other slots hold live, partially-decoded
        sequences — their caches are untouched. Returns
        (pool, first sampled token as a python int).
        """
        n = batch["tokens"].shape[0]
        if n != 1:
            raise ValueError(f"admit() takes one request, got batch {n}")
        prompt_len = int(batch["tokens"].shape[1])
        if prompt_len >= self.max_len:
            raise ValueError(f"prompt ({prompt_len}) must leave decode room "
                             f"in max_len ({self.max_len})")
        key = jax.random.PRNGKey(int(slot)) if key is None else key
        logits, caches = self.prefill(batch)
        caches = C.vectorize_pos(caches, 1)
        if self._replicated:
            caches = R.stack_replicas(caches, self.robust.m)
        pool = C.write_slot(pool, self._dims, caches, slot, prompt_len)
        tok = self._first_token(logits, key, sampling)
        return pool, int(tok[0])

    def decode_pool(self, pool: C.SlotPool, cur_tok, n_steps: int,
                    sampling: Sampling = GREEDY, key=None):
        """Advance every slot ``n_steps`` tokens in one dispatch.

        cur_tok: [n_slots] int32 — each slot's last token (free slots
        carry a dummy; their output is discarded by the scheduler).
        Returns (pool, toks [n_steps, n_slots]).
        """
        key = jax.random.PRNGKey(0) if key is None else key
        # the pool rests replica-stacked (admit/evict write [m, ...]
        # rows); the jitted loop runs the block replica-flat and
        # restores the layout before returning.
        fn = self._decode_loop_fn(n_steps, sampling, pool=True)
        diag = self.obs is not None and self.robust is not None
        if diag:
            # the diag aux masks inactive slots (stale caches decode
            # garbage — their disagreement rates would dilute the live
            # Byzantine signal), so drain with the live sample count.
            out = fn(self.params, pool.caches,
                     jnp.asarray(cur_tok, jnp.int32), key, pool.active)
        else:
            out = fn(self.params, pool.caches,
                     jnp.asarray(cur_tok, jnp.int32), key)
        toks, caches = out[0], out[1]
        if len(out) == 3:
            n_active = int(jax.device_get(pool.active).sum())
            self._drain_serve_diag(out[2], n_steps * n_active)
        lengths = jnp.where(pool.active, pool.lengths + n_steps, pool.lengths)
        return C.SlotPool(caches, lengths, pool.active), toks

    def evict(self, pool: C.SlotPool, slot: int) -> C.SlotPool:
        return C.evict_slot(pool, slot)
