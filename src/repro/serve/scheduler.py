"""Continuous-batching scheduler (DESIGN.md §6).

Host-side orchestration over the jitted engine: a FIFO request queue,
admission of variable-length prompts into free pool slots *mid-decode*,
and retirement of completed sequences (EOS or token budget) that frees
their slots for the next queued request. The device-side work stays in
two compiled programs — per-request prefill and the scanned
``decode_pool`` block — so the host loop touches the device once per
``decode_block`` tokens, not once per token.

Completion is detected at block granularity: a sequence that hits EOS
mid-block has its overshoot tokens trimmed on the host (the overshoot
writes land in a slot that is about to be recycled, and admission
overwrites every cache row including its position — stale state never
leaks into the next request).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Dict, List, Optional

import jax
import numpy as np

from ..obs.metrics import now as _now
from .engine import GREEDY, Sampling, ServeEngine

__all__ = ["Request", "Completion", "Scheduler"]


@dataclasses.dataclass
class Request:
    """One generation request. ``extras`` carries modality inputs
    (whisper frames / VLM patches) keyed as the model batch expects.
    ``submit_t`` is stamped by ``Scheduler.submit`` (obs clock) so
    admission can observe time-to-first-token including queue wait."""

    tokens: np.ndarray  # [S] int32 prompt
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    uid: Optional[int] = None
    extras: Optional[dict] = None
    submit_t: Optional[float] = None


@dataclasses.dataclass
class Completion:
    uid: int
    prompt: np.ndarray
    tokens: List[int]          # generated ids (includes EOS when hit)
    finished_by: str           # 'eos' | 'length' | 'rejected'


class Scheduler:
    """Drives admit -> decode -> retire over a ``ServeEngine`` pool.

    Telemetry (when the engine carries an ``obs.MetricsRegistry``): the
    queue/pool boundary records DESIGN.md §11's serve metrics — queue
    depth and slot occupancy gauges, admitted/rejected/retired/tokens
    counters, TTFT (submit -> first token, queue wait included) and
    per-token decode-step latency histograms. All host-side, outside
    the jitted programs; with ``obs=None`` no telemetry code runs.

    Compile exclusion: the first admission at a given prompt shape and
    the first decode block each trace + XLA-compile their program, so
    that dispatch is orders of magnitude above steady state. Those
    samples go to the ``serve.compile_s`` gauge (last-wins, like every
    gauge) instead of polluting the TTFT / decode-step histograms.
    """

    def __init__(self, engine: ServeEngine, *, decode_block: int = 4,
                 sampling: Sampling = GREEDY, seed: int = 0):
        if decode_block < 1:
            raise ValueError("decode_block must be >= 1")
        self.engine = engine
        self._obs = engine.obs
        self.decode_block = int(decode_block)
        self.sampling = sampling
        self.pool = engine.make_pool()
        n = engine.n_slots
        self.queue: collections.deque = collections.deque()
        self.completed: Dict[int, Completion] = {}
        self._uid = itertools.count()
        self._key = jax.random.PRNGKey(seed)
        self._slot_req: List[Optional[Request]] = [None] * n
        self._slot_out: List[List[int]] = [[] for _ in range(n)]
        self._cur_tok = np.zeros((n,), np.int32)
        # shapes whose prefill/admit programs have already compiled (the
        # prefill jit caches per prompt length + extras structure), and
        # whether the decode-block program has: first dispatches are
        # compile time, not latency samples.
        self._warm_prefill: set = set()
        self._decode_warm = False

    # -- submission ---------------------------------------------------------

    def submit(self, req: Request) -> int:
        if req.uid is None:
            req.uid = next(self._uid)
        req.tokens = np.asarray(req.tokens, np.int32)
        if req.tokens.ndim != 1 or req.tokens.size == 0:
            raise ValueError("prompt must be a non-empty 1-D token array")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        req.submit_t = _now()
        self.queue.append(req)
        return req.uid

    # -- internals ----------------------------------------------------------

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _free_slots(self) -> List[int]:
        return [s for s, r in enumerate(self._slot_req) if r is None]

    def _finish(self, slot: int, by: str) -> None:
        req = self._slot_req[slot]
        self.completed[req.uid] = Completion(
            uid=req.uid, prompt=req.tokens,
            tokens=self._slot_out[slot], finished_by=by)
        if self._obs is not None:
            self._obs.counter("serve.retired")
            self._obs.counter("serve.tokens_out", len(self._slot_out[slot]))
        self._slot_req[slot] = None
        self._slot_out[slot] = []
        self.pool = self.engine.evict(self.pool, slot)

    def _ingest(self, slot: int, new_tokens: List[int]) -> None:
        """Append a slot's new tokens, trimming at EOS / budget, and
        retire it when done."""
        req = self._slot_req[slot]
        out = self._slot_out[slot]
        for t in new_tokens:
            out.append(int(t))
            if req.eos_id is not None and int(t) == req.eos_id:
                self._finish(slot, "eos")
                return
            if len(out) >= req.max_new_tokens:
                self._finish(slot, "length")
                return

    def _admit(self) -> None:
        """Fill free slots from the queue (FIFO). A request that cannot
        fit its prompt plus token budget (with block overshoot) into a
        slot is rejected onto ``completed`` (finished_by='rejected')
        rather than wedging the queue head or corrupting a cache row."""
        for slot in self._free_slots():
            while self.queue:
                req = self.queue.popleft()
                # worst-case cache writes: prompt + budget + block
                # overshoot (retirement is block-granular).
                need = (req.tokens.shape[0] + req.max_new_tokens
                        + self.decode_block - 1)
                if need <= self.engine.max_len:
                    break
                self.completed[req.uid] = Completion(
                    uid=req.uid, prompt=req.tokens, tokens=[],
                    finished_by="rejected")
                if self._obs is not None:
                    self._obs.counter("serve.rejected")
            else:
                break
            batch = {"tokens": req.tokens[None]}
            if req.extras:
                # extras are per-request (unbatched) arrays, e.g. frames
                # [F, D] or patches [P, D]; prepend the batch-1 dim.
                for k, v in req.extras.items():
                    batch[k] = np.asarray(v)[None]
            shape_key = (req.tokens.shape[0],
                         tuple(sorted(req.extras)) if req.extras else ())
            t_admit = _now()
            self.pool, first = self.engine.admit(
                self.pool, slot, batch, sampling=self.sampling,
                key=self._next_key())
            if self._obs is not None:
                self._obs.counter("serve.admitted")
                if shape_key not in self._warm_prefill:
                    # cold shape: this admit traced + compiled the
                    # prefill program — compile time, not a TTFT sample.
                    self._obs.gauge("serve.compile_s", _now() - t_admit)
                elif req.submit_t is not None:
                    # admit() returned the first token as a host int, so
                    # the device work is done: submit -> here is TTFT
                    # with queue wait included.
                    self._obs.observe("serve.ttft_s", _now() - req.submit_t)
            self._warm_prefill.add(shape_key)
            self._slot_req[slot] = req
            self._slot_out[slot] = []
            self._cur_tok[slot] = first
            self._ingest(slot, [first])

    def _active_slots(self) -> List[int]:
        return [s for s, r in enumerate(self._slot_req) if r is not None]

    # -- main loop ----------------------------------------------------------

    def step(self) -> bool:
        """One admit + decode-block cycle. Returns False when idle."""
        self._admit()
        active = self._active_slots()
        if not active:
            return False
        if self._obs is not None:
            self._obs.gauge("serve.queue_depth", len(self.queue))
            self._obs.gauge("serve.slots_active", len(active))
        t0 = _now()
        self.pool, toks = self.engine.decode_pool(
            self.pool, self._cur_tok, self.decode_block,
            sampling=self.sampling, key=self._next_key())
        toks = np.asarray(toks)  # [decode_block, n_slots] (blocks: device
        #                          work done — the block time is real)
        if self._obs is not None:
            if self._decode_warm:
                self._obs.observe("serve.decode_step_s",
                                  (_now() - t0) / self.decode_block)
            else:
                # first block: trace + compile of the scanned decode
                # program dominates — record it as compile time.
                self._obs.gauge("serve.compile_s", _now() - t0)
        self._decode_warm = True
        self._cur_tok = toks[-1].astype(np.int32).copy()
        for slot in active:
            self._ingest(slot, list(toks[:, slot]))
        return True

    def run(self) -> Dict[int, Completion]:
        """Drain the queue. Returns completions keyed by request uid."""
        while self.queue or self._active_slots():
            self.step()
        return self.completed
