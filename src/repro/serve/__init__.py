"""repro.serve — continuous-batching inference engine with
Byzantine-robust replicated decoding (DESIGN.md §6).

    cache      slot-based KV cache pool (per-slot lengths, admit/evict)
    engine     prefill + fused scanned decode loop + sampling
    scheduler  continuous batching: queue, mid-decode admission, retirement
    robust     m-replica decode with robust logit aggregation + attacks
"""
from .cache import SlotPool, evict_slot, init_pool, pool_specs, write_slot
from .engine import GREEDY, Sampling, ServeEngine, sample_tokens
from .robust import RobustDecodeConfig, replica_mask, robust_logits
from .scheduler import Completion, Request, Scheduler

__all__ = [
    "SlotPool", "init_pool", "write_slot", "evict_slot", "pool_specs",
    "ServeEngine", "Sampling", "GREEDY", "sample_tokens",
    "RobustDecodeConfig", "replica_mask", "robust_logits",
    "Request", "Completion", "Scheduler",
]
