"""Byzantine-robust replicated decoding (DESIGN.md §6).

The paper's coordinate-wise robust aggregation over an untrusted worker
axis, applied to the serving path: the decode forward runs on ``m``
replicas, each replica emits logits for the same token positions, and
the served logits are the coordinate-wise robust aggregate
(VRMOM / median / trimmed mean from ``core/aggregators``) over the
replica axis. A replica that crashes, bit-flips or is actively
adversarial contributes one corrupted row per token; as long as fewer
than half the replicas are corrupted the aggregate — and hence every
greedy-decoded token — is unchanged (honest replicas are deterministic,
so their rows are identical and the coordinate-wise median of the
stacked logits IS the honest value; VRMOM's degenerate-scale guard,
DESIGN.md §2, reduces it to exactly the median in that regime).

``core/attacks`` fault injection is wired in for testing: the attack
corrupts the logit rows of the replicas selected by ``replica_mask``
before aggregation, modelling faulty workers on the wire.

Replicas map onto the mesh worker axes (``dist/ctx`` conventions): the
replica-stacked cache tree puts the replica dim on ``("pod", "data")``
via ``replica_specs``, so each replica's forward runs resident on its
own worker shard and only the [m, B, V] logits cross the wire —
coordinate-wise aggregation needs no other communication.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core import aggregators as AGG
from ..core import attacks as ATK
from ..models import model as M

__all__ = [
    "RobustDecodeConfig",
    "replica_mask",
    "stack_replicas",
    "replica_specs",
    "robust_logits",
    "robust_decode_step",
]


class RobustDecodeConfig(NamedTuple):
    """Static config for replicated robust decode.

    m:          number of decode replicas (worker-axis size).
    aggregator: any coordinate-wise ``core/aggregators`` name. Default
                vrmom; with identical honest rows its MAD scale is 0 and
                the degenerate guard returns the exact median (§2), so
                greedy tokens are provably unchanged for any aggregator
                whose breakdown point exceeds alpha.
    K:          VRMOM quantile levels (ignored by other aggregators).
    attack:     ``core/attacks`` name injected on the corrupted rows
                ("none" in production — real faults need no simulation).
    alpha:      corrupted fraction; floor(alpha * m) rows are attacked.
    """

    m: int = 8
    aggregator: str = "vrmom"
    K: int = 8
    attack: str = "none"
    alpha: float = 0.25


def replica_mask(m: int, alpha: float) -> jnp.ndarray:
    """[m] bool — the last floor(alpha*m) replicas are corrupted.

    Serving has no privileged master row; the aggregators are
    permutation-invariant so the choice of rows is WLOG. floor(alpha*m)
    with alpha < 1/2 keeps an honest strict majority.
    """
    n_byz = int(math.floor(alpha * m))
    if n_byz >= (m + 1) // 2:
        raise ValueError(f"alpha={alpha} corrupts {n_byz}/{m}: no honest "
                         "majority, aggregation cannot be robust")
    return jnp.arange(m) >= m - n_byz


def stack_replicas(tree, m: int):
    """Broadcast a cache tree to a leading replica dim: x -> [m, *x.shape]."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), tree)


def replica_specs(tree, worker_axes):
    """P-tree placing the leading replica dim on the mesh worker axes."""
    from jax.sharding import PartitionSpec as P

    wa = tuple(worker_axes)

    def one(x):
        return P(wa if wa else None, *([None] * (x.ndim - 1)))

    return jax.tree.map(one, tree)


def _aggregate(logits_r, rcfg: RobustDecodeConfig):
    """[m, B, V] replica logits -> [B, V] robust aggregate (f32 wire)."""
    kw = {}
    if rcfg.aggregator == "vrmom":
        kw["K"] = rcfg.K
    elif rcfg.aggregator == "trimmed_mean":
        # trim exactly the corrupted fraction per end; the default 0.1
        # would trim int(0.1*m)=0 rows at m=8 and degrade to the mean.
        kw["beta"] = rcfg.alpha
    fn = AGG.get(rcfg.aggregator, **kw)
    return fn(logits_r.astype(jnp.float32), axis=0)


def robust_logits(logits_r, rcfg: RobustDecodeConfig,
                  key: Optional[jax.Array] = None):
    """Corrupt the attacked rows, then robustly aggregate.

    logits_r: [m, B, V] per-replica logits (the wire tensor). Returns
    [B, V] f32 aggregated logits.
    """
    if rcfg.attack != "none":
        if key is None:
            raise ValueError("attack injection needs a PRNG key")
        mask = replica_mask(rcfg.m, rcfg.alpha)
        logits_r = ATK.get(rcfg.attack)(key, logits_r, mask)
    return _aggregate(logits_r, rcfg)


def robust_decode_step(params, cfg, rep_caches, token,
                       rcfg: RobustDecodeConfig,
                       key: Optional[jax.Array] = None, window="cfg"):
    """One replicated decode step.

    rep_caches: cache tree with leading replica dim [m, ...] (honest
    replicas hold identical state; a real deployment shards the dim over
    the worker axes via ``replica_specs``). token: [B] int32 — the same
    tokens go to every replica. ``window`` is forwarded to the model so
    the robust path uses the same cache geometry as the plain one.
    Returns ([B, V] f32 robust logits, updated rep_caches).
    """
    logits_r, new_caches = jax.vmap(
        lambda c: M.decode_step(params, cfg, c, token,
                                window=window))(rep_caches)
    return robust_logits(logits_r, rcfg, key), new_caches
