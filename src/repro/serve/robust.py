"""Byzantine-robust replicated decoding (DESIGN.md §6).

The paper's coordinate-wise robust aggregation over an untrusted worker
axis, applied to the serving path: the decode forward runs on ``m``
replicas, each replica emits logits for the same token positions, and
the served logits are the coordinate-wise robust aggregate (a
``core.estimator.Estimator`` — VRMOM / median / trimmed mean) over the
replica axis. A replica that crashes, bit-flips or is actively
adversarial contributes one corrupted row per token; as long as fewer
than half the replicas are corrupted the aggregate — and hence every
greedy-decoded token — is unchanged (honest replicas are deterministic,
so their rows are identical and the coordinate-wise median of the
stacked logits IS the honest value; VRMOM's degenerate-scale guard,
DESIGN.md §2, reduces it to exactly the median in that regime).

Aggregation runs on the Estimator's fused backend (DESIGN.md §7): the
``[m, B, V]`` logit stack goes through the one-pass sorting-network
kernel *inside* the decode ``lax.scan`` — not a per-token composition of
jnp medians — which is what closes most of the robust-decode overhead
recorded in ``BENCH_serve.json``.

``core/attacks`` fault injection is wired in for testing: the attack
corrupts the logit rows of the replicas selected by ``replica_mask``
before aggregation, modelling faulty workers on the wire.

Replicas map onto the mesh worker axes (``dist/ctx`` conventions): the
replica-stacked cache tree puts the replica dim on ``("pod", "data")``
via ``replica_specs``, so each replica's forward runs resident on its
own worker shard and only the [m, B, V] logits cross the wire —
coordinate-wise aggregation needs no other communication.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Union

import jax
import jax.numpy as jnp

from ..core import attacks as ATK
from ..core.estimator import Estimator
from ..lint.hashguard import check_hashable_fields
from ..models import model as M

__all__ = [
    "RobustDecodeConfig",
    "replica_mask",
    "stack_replicas",
    "replica_specs",
    "flatten_replicas",
    "unflatten_replicas",
    "robust_logits",
    "robust_sample",
    "robust_decode_step",
]


@dataclasses.dataclass(frozen=True)
class RobustDecodeConfig:
    """Static config for replicated robust decode.

    m:          number of decode replicas (worker-axis size).
    estimator:  a coordinate-wise ``core.estimator.Estimator``, or a
                method name (coerced: ``K`` binds to VRMOM, and
                trimmed_mean's beta binds to ``alpha`` — the default 0.1
                would trim int(0.1*m)=0 rows at m=8 and silently degrade
                to the mean). Default vrmom; with identical honest rows
                its MAD scale is 0 and the degenerate guard returns the
                exact median (§2), so greedy tokens are provably
                unchanged for any estimator whose breakdown point
                exceeds alpha.
    K:          VRMOM quantile levels (used when coercing a name).
    attack:     ``core/attacks`` name injected on the corrupted rows
                ("none" in production — real faults need no simulation).
    alpha:      corrupted fraction; floor(alpha * m) rows are attacked.
    share_replica_compute:
                single-host emulation mode. The attack model is
                logit-level (``core/attacks`` corrupts rows of the
                [m, B, V] stack, never replica state), every replica is
                the same deterministic function of (params, cache,
                aggregated token), and all replicas consume the same
                aggregated feedback — so honest replica caches stay
                bit-identical forever and the m decode forwards compute
                the same rows m times. ``True`` (default) computes the
                forward ONCE and broadcasts its logits into the [m, B,
                V] wire stack: tokens are bit-identical to the
                replicated emulation (the same argument ``_first_token``
                already makes for the prefill logits), per-slot KV
                drops m-fold, and wall-clock matches a deployment whose
                m workers run in parallel. ``False`` keeps the
                replicated-forward emulation — every replica's forward
                executed serially — as the reference the equivalence is
                tested against (and the honest cost model for a host
                that really must run all m replicas itself).
    fuse_tail:  run aggregation + sampling as ONE Pallas dispatch
                (``Estimator.apply_sample``, DESIGN.md §12) when the
                resolved backend is the fused kernel and the sampling
                method has a fused epilogue (greedy / top-k). ``False``
                restores the unfused tail — aggregate kernel, then a jnp
                argmax/top-k pass — which the fusion-attribution
                benchmark uses as its baseline. Greedy tokens are
                bit-identical either way.

    The spec is validated against ``m`` at construction (trace time):
    a trimmed_mean that trims zero rows, or a whole-vector estimator
    (which cannot aggregate a logit stack coordinate-wise), raises here
    rather than serving non-robust tokens.
    """

    m: int = 8
    estimator: Union[str, Estimator] = "vrmom"
    K: int = 8
    attack: str = "none"
    alpha: float = 0.25
    fuse_tail: bool = True
    share_replica_compute: bool = True

    def __post_init__(self):
        est = self.estimator
        if isinstance(est, str):
            est = Estimator(method=est)
            if est.method == "vrmom":
                est = est._replace(K=self.K)
            if est.method == "trimmed_mean":
                est = est._replace(beta=self.alpha)
        elif not isinstance(est, Estimator):
            raise TypeError(
                f"estimator must be a method name or an Estimator, "
                f"got {type(est)!r}")
        # Replica logits are complete worker rows ([m, B, V] flattens
        # to [m, B*V]), so the adaptive tier (§14) is legal here along
        # with every coordinate-wise method; whole-vector selectors
        # stay rejected.
        est.require_stackable(
            "replicated logit aggregation (serve.robust)")
        est.validate(self.m)
        object.__setattr__(self, "estimator", est)
        # RobustDecodeConfig is a jit static arg on the decode loop — an
        # unhashable field would retrace or TypeError at that boundary;
        # fail here instead, naming the field (reprolint RL004).
        check_hashable_fields(self)


def replica_mask(m: int, alpha: float) -> jnp.ndarray:
    """[m] bool — the last floor(alpha*m) replicas are corrupted.

    Serving has no privileged master row; the estimators are
    permutation-invariant so the choice of rows is WLOG. floor(alpha*m)
    with alpha < 1/2 keeps an honest strict majority.
    """
    n_byz = int(math.floor(alpha * m))
    if n_byz >= (m + 1) // 2:
        raise ValueError(f"alpha={alpha} corrupts {n_byz}/{m}: no honest "
                         "majority, aggregation cannot be robust")
    return jnp.arange(m) >= m - n_byz


def stack_replicas(tree, m: int):
    """Broadcast a cache tree to a leading replica dim: x -> [m, *x.shape]."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), tree)


def replica_specs(tree, worker_axes):
    """P-tree placing the leading replica dim on the mesh worker axes."""
    from jax.sharding import PartitionSpec as P

    wa = tuple(worker_axes)

    def one(x):
        return P(wa if wa else None, *([None] * (x.ndim - 1)))

    return jax.tree.map(one, tree)


_NO_BATCH_DIM = -1  # mirrors cache._NO_SLOT_DIM: leaf has no batch dim


def flatten_replicas(rep_tree, dims, m: int):
    """Replica-stacked tree ``[m, ...]`` -> flat-batch tree (replica-major).

    ``dims``: per-leaf batch-dim index of the *unstacked* tree (the
    structural probe of ``serve.cache.slot_dims``). Each leaf's replica
    axis merges into its batch axis — row ``r*B + b`` is replica r of
    sequence b — so the m-replica forward is ONE model call at batch
    ``m*B`` instead of a vmap over m separate calls: on a single host
    that removes the per-replica loop XLA cannot always flatten, and on
    a mesh the merged batch dim sharded over the worker axes places each
    replica's rows on its own shard exactly like ``replica_specs`` does
    for the stacked layout (batch axes == worker axes, DESIGN.md §6).

    Batch-free leaves (e.g. per-layer scalar cache positions) are
    replica-invariant by construction — ``stack_replicas`` broadcasts
    them and honest replicas update them identically (attacks corrupt
    the *logit wire*, never replica-local state) — so replica 0's value
    is taken and re-broadcast on unflatten.
    """
    def one(x, d):
        if d == _NO_BATCH_DIM:
            return x[0]
        xm = jnp.moveaxis(x, 0, d)  # replica axis lands before batch axis
        return xm.reshape(xm.shape[:d] + (m * xm.shape[d + 1],)
                          + xm.shape[d + 2:])

    return jax.tree.map(one, rep_tree, dims)


def unflatten_replicas(flat_tree, dims, m: int):
    """Inverse of ``flatten_replicas``: restore the leading replica dim."""
    def one(x, d):
        if d == _NO_BATCH_DIM:
            return jnp.broadcast_to(x[None], (m,) + x.shape)
        xr = x.reshape(x.shape[:d] + (m, x.shape[d] // m) + x.shape[d + 1:])
        return jnp.moveaxis(xr, d, 0)

    return jax.tree.map(one, flat_tree, dims)


def robust_logits(logits_r, rcfg: RobustDecodeConfig,
                  key: Optional[jax.Array] = None, *,
                  with_diag: bool = False):
    """Corrupt the attacked rows, then robustly aggregate.

    logits_r: [m, B, V] per-replica logits (the wire tensor). Returns
    [B, V] f32 aggregated logits via the Estimator's fused backend.
    ``with_diag`` additionally returns the per-token replica-
    disagreement rate ``[B] f32`` (``obs.diag.replica_disagreement``):
    the fraction of replicas whose argmax differs from the served token
    — the live Byzantine signal, 0 for an all-honest replica set.
    """
    if rcfg.attack != "none":
        if key is None:
            raise ValueError("attack injection needs a PRNG key")
        mask = replica_mask(rcfg.m, rcfg.alpha)
        logits_r = ATK.get(rcfg.attack)(key, logits_r, mask)
    agg = rcfg.estimator.apply(logits_r.astype(jnp.float32), axis=0)
    if with_diag:
        from ..obs.diag import replica_disagreement

        return agg, replica_disagreement(logits_r, agg)
    return agg


def robust_sample(logits_r, rcfg: RobustDecodeConfig,
                  key: Optional[jax.Array], skey, sc, *,
                  with_diag: bool = False):
    """The whole robust-decode tail: attack, aggregate, sample.

    logits_r: [m, B, V] per-replica logits; ``key`` the attack-injection
    key (may be None when ``rcfg.attack == "none"``), ``skey`` the
    sampling key, ``sc`` an ``engine.Sampling``. Returns ``tok [B]
    int32`` (plus the replica-disagreement rate ``[B] f32`` when
    ``with_diag``).

    With ``rcfg.fuse_tail`` and a greedy/top-k sampling method this is
    ONE fused dispatch (``Estimator.apply_sample``, DESIGN.md §12):
    aggregation and token selection share the VMEM-resident aggregate,
    and for greedy-without-diagnostics the [B, V] aggregate is never
    written back to HBM at all. Greedy tokens are bit-identical to
    ``sample_tokens(robust_logits(...))``; top-k draws the categorical
    over the fused kernel's [B, k] (value, index) lists, reproducing the
    masked-vocab sampling distribution. Temperature-only sampling needs
    the full [B, V] aggregate and always takes the unfused tail.
    """
    if not (rcfg.fuse_tail and sc.method in ("greedy", "top_k")):
        from .engine import sample_tokens

        out = robust_logits(logits_r, rcfg, key, with_diag=with_diag)
        agg, dis = out if with_diag else (out, None)
        tok = sample_tokens(agg, skey, sc)
        return (tok, dis) if with_diag else tok
    if rcfg.attack != "none":
        if key is None:
            raise ValueError("attack injection needs a PRNG key")
        mask = replica_mask(rcfg.m, rcfg.alpha)
        logits_r = ATK.get(rcfg.attack)(key, logits_r, mask)
    x = logits_r.astype(jnp.float32)
    if sc.method == "greedy":
        agg, tok = rcfg.estimator.apply_sample(x, with_agg=with_diag)
    else:
        if sc.top_k <= 0:
            raise ValueError("top_k sampling needs top_k > 0")
        agg, topv, topi = rcfg.estimator.apply_sample(
            x, top_k=sc.top_k, with_agg=with_diag)
        l = topv / max(sc.temperature, 1e-6)
        idx = jax.random.categorical(skey, l, axis=-1)
        tok = jnp.take_along_axis(topi, idx[:, None], axis=1)[:, 0]
        tok = tok.astype(jnp.int32)
    if with_diag:
        from ..obs.diag import replica_disagreement

        return tok, replica_disagreement(logits_r, agg)
    return tok


def robust_decode_step(params, cfg, rep_caches, token,
                       rcfg: RobustDecodeConfig,
                       key: Optional[jax.Array] = None, window="cfg"):
    """One replicated decode step (vmapped reference semantics).

    rep_caches: cache tree with leading replica dim [m, ...] (honest
    replicas hold identical state; a real deployment shards the dim over
    the worker axes via ``replica_specs``). token: [B] int32 — the same
    tokens go to every replica. ``window`` is forwarded to the model so
    the robust path uses the same cache geometry as the plain one.
    Returns ([B, V] f32 robust logits, updated rep_caches).

    The engine's scanned decode loop runs the equivalent replica-FLAT
    form instead (``flatten_replicas``: one ``decode_step`` at batch
    m*B) — this vmapped version is the reference and the per-step
    debugging baseline.

    With ``rcfg.share_replica_compute`` the caches are UNstacked (plain
    [B, ...] state): one forward runs and its logits broadcast into the
    replica stack — see the config docstring for why that is
    token-identical to the vmapped form.
    """
    if rcfg.share_replica_compute:
        logits, new_caches = M.decode_step(params, cfg, rep_caches, token,
                                           window=window)
        logits_r = jnp.broadcast_to(logits, (rcfg.m,) + logits.shape)
        return robust_logits(logits_r, rcfg, key), new_caches
    logits_r, new_caches = jax.vmap(
        lambda c: M.decode_step(params, cfg, c, token,
                                window=window))(rep_caches)
    return robust_logits(logits_r, rcfg, key), new_caches
