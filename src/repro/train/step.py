"""Step builders: Byzantine-robust train_step + prefill/decode serve steps.

``make_train_step`` wires the paper's technique into the training loop:
per-worker gradients (vmap over the worker axis = data mesh axes),
optional simulated Byzantine corruption, robust aggregation
(repro.dist.robust_reduce), optimizer update. Everything jit-compatible
and fully sharded; the returned callable carries .in_shardings /
.out_shardings for jit/lower.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..core import attacks as atk
from ..core.estimator import Estimator
from ..dist import ctx as CTX
from ..dist import robust_reduce as RR
from ..dist import sharding as S
from ..models import model as M
from .. import optim as O


@dataclasses.dataclass(frozen=True)
class TrainSetup:
    step_fn: Callable
    params_specs: object
    opt_specs: object
    batch_axes: tuple
    worker_axes: tuple
    n_workers: int
    # Adaptive estimators only (est.adaptive): zero-arg callable building
    # the initial core.adaptive.AdaptiveState carry; the step then takes
    # it as a trailing arg and returns the updated state after the loss
    # (RL211: adaptive state is an explicit carry, never Python state).
    init_state: Optional[Callable] = None




def make_train_step(
    cfg: ArchConfig,
    mesh,
    *,
    estimator=Estimator(),  # Estimator spec or method name (coerced)
    mode: str = "stacked-rrs",  # stacked-rrs | stacked-auto | mean | inloop
    optimizer=None,
    lr: float = 1e-3,
    byzantine_frac: float = 0.0,
    attack: str = "gaussian",
    global_batch: Optional[int] = None,
    microbatch: Optional[int] = None,
    with_diag: bool = False,
    reduce_backend: str = "rrs",
    consensus=None,
    fault_plan=None,
    weights_beta: float = 0.5,
    momentum: float = 0.0,
) -> TrainSetup:
    """``estimator``: a ``core.estimator.Estimator`` (or method name) —
    the single aggregation spec threaded to every robust-reduction mode.
    ``microbatch``: gradient-accumulation steps per worker (None = auto:
    one-sequence microbatches when seq_len >= 2048 — keeps remat-stored
    layer boundaries at one sequence/chip, see EXPERIMENTS.md §Perf).
    ``with_diag``: the step additionally returns an
    ``obs.diag.AggDiagnostics`` aux (per-worker suspicion scores,
    alpha-hat, pre/post norms) — static-shape arrays riding the same jit,
    so enabling it changes the step signature but adds no host sync.
    ``reduce_backend``: ``"rrs"`` keeps the coordinator-style modes as
    selected by ``mode``; ``"consensus"`` reroutes the stacked wire
    through peer-to-peer approximate consensus (DESIGN.md §13), with
    ``consensus`` (a ``dist.consensus.ConsensusConfig``; default derives
    ``f`` from ``byzantine_frac``) and ``fault_plan`` (a
    ``dist.faults.FaultPlan`` of injected dropout/crashes/stragglers).
    In consensus mode the step always returns a
    ``dist.consensus.ConsensusAux`` after the loss — the step signature
    becomes ``(params, opt, loss, caux[, diag])``.
    Adaptive estimators (``est.adaptive``, DESIGN.md §14) reroute the
    stacked wire through ``aggregate_stacked_adaptive``: the step takes
    an ``AdaptiveState`` as a trailing argument (build it with
    ``TrainSetup.init_state()``) and returns the new state after the
    loss — ``(params, opt, loss, agg_state[, diag])``. ``weights_beta``
    / ``momentum`` are the adaptive EMA knobs (ignored otherwise)."""
    est = Estimator.coerce(estimator)
    if with_diag and mode == "inloop":
        raise ValueError(
            "with_diag is unavailable in inloop mode: IB-RRS aggregates "
            "inside the backward pass and the per-worker gradient stack "
            "never materializes to diagnose. Use mode='stacked-rrs'.")
    worker_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_workers = 1
    for a in worker_axes:
        n_workers *= mesh.shape[a]
    batch_axes = worker_axes
    optimizer = optimizer or O.get(cfg.optimizer, lr=lr)

    if reduce_backend not in ("rrs", "consensus"):
        raise ValueError(f"unknown reduce_backend {reduce_backend!r}; "
                         "known: ('rrs', 'consensus')")
    if reduce_backend == "consensus":
        from ..dist.consensus import ConsensusConfig

        if mode == "inloop":
            raise ValueError(
                "reduce_backend='consensus' needs the materialized "
                "stacked wire; inloop (IB-RRS) aggregates inside the "
                "backward pass. Use a stacked mode.")
        mode = "stacked-consensus"
        if consensus is None:
            n_byz_hint = int(byzantine_frac * (n_workers - 1))
            consensus = ConsensusConfig(f=max(n_byz_hint, 1))
        if n_workers > 1:
            consensus.validate(n_workers)  # fail at build, not at trace

    if est.adaptive:
        if mode == "inloop":
            raise ValueError(
                "adaptive estimators need the materialized stacked wire; "
                "inloop (IB-RRS) aggregates inside the backward pass. "
                "Use a stacked mode.")
        if mode == "stacked-consensus":
            raise ValueError(
                "adaptive estimators are unavailable on the consensus "
                "backend: peer rounds exchange coordinate slices, never "
                "complete worker rows (DESIGN.md §13). Use "
                "reduce_backend='rrs'.")
        mode = "stacked-adaptive"

    params_shapes = M.abstract_init(cfg)
    params_specs = S.param_specs(params_shapes, mesh)
    opt_shapes = jax.eval_shape(optimizer.init, params_shapes)
    opt_specs = S.opt_state_specs(opt_shapes, params_shapes, params_specs)

    init_state = None
    if est.adaptive:
        # The adaptive wire ravels every leaf, so the census dimension is
        # the total parameter count.
        wire_dim = sum(math.prod(l.shape)
                       for l in jax.tree.leaves(params_shapes))
        init_state = lambda: est.init_adaptive_state(n_workers, wire_dim)

    n_byz = int(byzantine_frac * (n_workers - 1))
    mask = jnp.arange(n_workers) >= (n_workers - n_byz)
    attack_fn = atk.get(attack)

    def loss_fn(p, b):
        return M.loss(p, cfg, b)

    def _micro_for(batch_w):
        if microbatch is not None:
            return microbatch
        tokens = batch_w["tokens"]
        per_worker, seq = tokens.shape[1], tokens.shape[2]
        return per_worker if seq >= 2048 else 1

    def worker_grad(params, b):
        """Per-worker loss+grad with gradient accumulation over
        1/micro-sized slices of the worker's batch (f32 accumulator)."""
        micro = _micro_for_static[0]
        if micro <= 1:
            return jax.value_and_grad(loss_fn)(params, b)
        bm = jax.tree.map(
            lambda x: x.reshape((micro, x.shape[0] // micro) + x.shape[1:]),
            b)
        acc0 = (jnp.zeros(()),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params))

        def mb(acc, bi):
            l, g = jax.value_and_grad(loss_fn)(params, bi)
            return (acc[0] + l,
                    jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32),
                                 acc[1], g)), None

        (l, g), _ = jax.lax.scan(mb, acc0, bm)
        g = jax.tree.map(lambda x, p: (x / micro).astype(p.dtype), g, params)
        return l / micro, g

    _micro_for_static = [1]

    def train_step(params, opt_state, batch, key, agg_state=None):
      with CTX.mesh_context(mesh):
          if mode == "inloop":
              # IB-RRS: global backward; heavy matmul grads are robust-
              # reduced inside the bwd pass via robust_dot. Gradient
              # accumulation over batch slices bounds activation memory
              # (the aggregate of per-micro VRMOMs stays robust: each
              # micro-step aggregation already bounds Byzantine influence).
              B = batch["tokens"].shape[0]
              seq = batch["tokens"].shape[1]
              micro = microbatch if microbatch is not None else (
                  max(B // n_workers, 1) if seq >= 2048 else 1)
              if B % max(n_workers, 1):
                  raise ValueError(
                      f"inloop global batch {B} must be divisible by "
                      f"the {n_workers} workers")
              per_worker = B // max(n_workers, 1)
              if micro > 1 and per_worker % micro:
                  raise ValueError(
                      f"inloop microbatch={micro} must divide the "
                      f"per-worker batch {per_worker}")
              with RR.robust_backward(mesh, worker_axes, est):
                  if micro > 1:
                      # STRIDED split: every micro-slice must contain an
                      # equal worker-major block from each physical worker,
                      # or robust_dot's per-worker grouping inside the
                      # backward stops corresponding to workers and a
                      # single Byzantine worker owns whole micro-steps.
                      def split_micro(x):
                          b = x.shape[0]
                          x = x.reshape((n_workers, micro,
                                         b // (n_workers * micro))
                                        + x.shape[1:])
                          x = jnp.swapaxes(x, 0, 1)
                          return x.reshape((micro, b // micro) + x.shape[3:])

                      bm = jax.tree.map(split_micro, batch)
                      acc0 = (jnp.zeros(()),
                              jax.tree.map(lambda p: jnp.zeros(
                                  p.shape, jnp.float32), params))

                      def mb(acc, bi):
                          l, g = jax.value_and_grad(loss_fn)(params, bi)
                          g = jax.lax.with_sharding_constraint(
                              g, S.to_named(mesh, params_specs))
                          return (acc[0] + l, jax.tree.map(
                              lambda a, gg: a + gg.astype(jnp.float32),
                              acc[1], g)), None

                      (loss, grads), _ = jax.lax.scan(mb, acc0, bm)
                      loss = loss / micro
                      grads = jax.tree.map(
                          lambda x, p: (x / micro).astype(p.dtype),
                          grads, params)
                  else:
                      loss, grads = jax.value_and_grad(loss_fn)(params, batch)
              agg = grads
          else:
              # split the global batch into per-worker microbatches
              def split(x):
                  b = x.shape[0]
                  return x.reshape((n_workers, b // n_workers) + x.shape[1:])

              batch_w = jax.tree.map(split, batch)
              _micro_for_static[0] = _micro_for(batch_w)
              # spmd_axis_name pins every batched intermediate's worker
              # dim to the data axes — without it XLA materializes
              # worker-replicated activations in the backward pass.
              losses, grads = jax.vmap(
                  worker_grad, in_axes=(None, 0),
                  spmd_axis_name=worker_axes,
              )(params, batch_w)
              loss = jnp.mean(losses)
              stacked_specs = S.stacked_grad_specs(
                  params_specs, worker_axes, mesh, shapes=params_shapes)
              grads = jax.lax.with_sharding_constraint(
                  grads, S.to_named(mesh, stacked_specs))
              if mode == "stacked-consensus":
                  key, k_cons = jax.random.split(key)
              if n_byz:
                  grads = jax.tree.map(
                      lambda g: attack_fn(key, g, mask), grads)
              if mode == "stacked-consensus":
                  agg = RR.aggregate(grads, mesh, worker_axes, mode=mode,
                                     est=est, specs=stacked_specs,
                                     with_diag=with_diag,
                                     consensus=consensus, plan=fault_plan,
                                     key=k_cons,
                                     pin_mask=mask if n_byz else None)
              elif mode == "stacked-adaptive":
                  agg = RR.aggregate_stacked_adaptive(
                      grads, agg_state, est, with_diag=with_diag,
                      weights_beta=weights_beta, momentum=momentum)
              else:
                  agg = RR.aggregate(grads, mesh, worker_axes, mode=mode,
                                     est=est, specs=stacked_specs,
                                     with_diag=with_diag)
          diag = caux = new_state = None
          if mode == "stacked-consensus":
              if with_diag:
                  agg, caux, diag = agg
              else:
                  agg, caux = agg
          elif mode == "stacked-adaptive":
              if with_diag:
                  agg, new_state, diag = agg
              else:
                  agg, new_state = agg
          elif with_diag:
              agg, diag = agg
          agg = jax.lax.with_sharding_constraint(
              agg, S.to_named(mesh, params_specs))
          new_params, new_opt = optimizer.update(agg, opt_state, params)
          new_params = jax.lax.with_sharding_constraint(
              new_params, S.to_named(mesh, params_specs))
          out = (new_params, new_opt, loss)
          if new_state is not None:
              out = out + (new_state,)
          if caux is not None:
              out = out + (caux,)
          if with_diag:
              out = out + (diag,)
          return out

    return TrainSetup(
        step_fn=train_step,
        params_specs=params_specs,
        opt_specs=opt_specs,
        batch_axes=batch_axes,
        worker_axes=worker_axes,
        n_workers=n_workers,
        init_state=init_state,
    )


def make_serve_steps(cfg: ArchConfig, mesh, *, shape, window="cfg"):
    """Returns (prefill_fn, decode_fn, cache_spec_fn) with spec helpers."""
    batch_axes = S.batch_axes_for(mesh, shape.global_batch)

    def prefill_fn(params, batch):
        with CTX.mesh_context(mesh):
            logits, caches = M.prefill(params, cfg, batch, window=window,
                                       cache_len=shape.seq_len,
                                       last_only=True)
            return logits, caches

    def decode_fn(params, caches, token):
        with CTX.mesh_context(mesh):
            return M.decode_step(params, cfg, caches, token, window=window)

    def cache_shapes():
        return jax.eval_shape(
            lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len,
                                 window=window))

    def specs():
        cs = S.cache_specs(cfg, cache_shapes(), mesh, batch_axes,
                           global_batch=shape.global_batch)
        return cs

    return prefill_fn, decode_fn, cache_shapes, specs, batch_axes
