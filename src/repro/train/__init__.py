from . import step
from .step import make_serve_steps, make_train_step
