"""repro: Byzantine-robust distributed training with VRMOM (JAX/TPU).

Faithful implementation of Tu, Liu, Mao & Chen (2021) — the VRMOM
estimator and the RCSL algorithm — integrated as a first-class robust
gradient-aggregation layer in a multi-pod JAX training/serving framework.
See README.md / DESIGN.md / EXPERIMENTS.md.
"""
__version__ = "1.0.0"
