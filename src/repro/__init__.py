"""repro: Byzantine-robust distributed training with VRMOM (JAX/TPU).

Faithful implementation of Tu, Liu, Mao & Chen (2021) — the VRMOM
estimator, the RCSL algorithm, and the plug-in asymptotic-normality
inference layer — integrated as a first-class robust
gradient-aggregation layer in a multi-pod JAX training/serving
framework. See README.md for the subsystem map and results, DESIGN.md
§1-§9 for the design record.
"""
__version__ = "1.0.0"
