#!/usr/bin/env python
"""Metrics JSONL -> merged summary / Prometheus text (DESIGN.md §11).

Every telemetry producer in the repo — benchmarks/serve.py, the example
runners, launch/dryrun.py's HLO cost summaries — appends records to a
shared JSONL file via ``repro.obs.sinks.JsonlSink``. This CLI folds such
a file back into one summary (counters sum, gauges last-wins, histograms
merge on matching edges) and renders it:

    python scripts/metrics_dump.py metrics.jsonl                # prometheus
    python scripts/metrics_dump.py metrics.jsonl --format json
    python scripts/metrics_dump.py metrics.jsonl --out metrics.prom
    python scripts/metrics_dump.py a.jsonl b.jsonl              # multi-file

Percentile summaries of every histogram ride along as synthetic gauges
(``<name>_p50`` / ``_p95`` / ``_p99``) unless ``--no-percentiles``.

Stdlib-only (the obs host layer imports no jax): usable in docs CI and
on machines without the accelerator stack.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.obs.metrics import Histogram  # noqa: E402
from repro.obs.sinks import (merge_records, prometheus_text,  # noqa: E402
                             read_jsonl)


def summarize(paths, percentiles=(50, 95, 99)) -> dict:
    records = []
    for p in paths:
        records.extend(read_jsonl(p))
    summary = merge_records(records)
    for name, snap in summary["histograms"].items():
        h = Histogram.from_snapshot(snap)
        if h.count == 0:
            continue
        for q in percentiles:  # q in percent, as Histogram.percentile takes
            summary["gauges"][f"{name}_p{int(q)}"] = h.percentile(q)
    return summary


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="metrics_dump", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="+", help="metrics JSONL file(s)")
    ap.add_argument("--format", choices=("prometheus", "json"),
                    default="prometheus")
    ap.add_argument("--out", default=None, help="write here instead of stdout")
    ap.add_argument("--no-percentiles", action="store_true",
                    help="skip the synthetic p50/p95/p99 gauges")
    args = ap.parse_args(argv)

    for p in args.paths:
        if not os.path.isfile(p):
            print(f"metrics_dump: no such file: {p}", file=sys.stderr)
            return 2
    summary = summarize(args.paths,
                        percentiles=() if args.no_percentiles
                        else (50, 95, 99))
    if args.format == "json":
        text = json.dumps(summary, indent=2, sort_keys=True) + "\n"
    else:
        text = prometheus_text(summary)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    else:
        sys.stdout.write(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
