#!/usr/bin/env python
"""reprolint CLI — AST rules + abstract trace auditor (DESIGN.md §10).

Usage:
    python scripts/reprolint.py [paths...]            # default: src tests
    python scripts/reprolint.py --audit --host-devices 8
    python scripts/reprolint.py benchmarks examples --warn-only
    python scripts/reprolint.py --format json --out reprolint.json

Exit status: 1 if any non-waived error finding or any audit failure,
0 otherwise. ``--warn-only`` downgrades findings to warnings (exit 0),
printing the count — the benchmarks/examples drift monitor.

``--host-devices N`` must set XLA_FLAGS before jax is imported, which is
why the auditor import happens inside main() after the env mutation.
"""
from __future__ import annotations

import argparse
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="reprolint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files/directories to lint (default: src tests)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--out", default=None,
                    help="also write the JSON report to this file")
    ap.add_argument("--audit", action="store_true",
                    help="run the RL2xx trace auditor (imports jax)")
    ap.add_argument("--host-devices", type=int, default=0, metavar="N",
                    help="force N host CPU devices for the auditor mesh")
    ap.add_argument("--warn-only", action="store_true",
                    help="downgrade findings to warnings (exit 0)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.host_devices:
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{args.host_devices}").strip()

    from repro.lint import AST_RULES, AUDIT_CHECKS, Report, lint_paths

    if args.list_rules:
        for r in AST_RULES + AUDIT_CHECKS:
            print(f"{r.id}  {r.name:28s} {r.established}")
        return 0

    severity = "warning" if args.warn_only else "error"
    findings = lint_paths(args.paths, ROOT, severity=severity)

    audit = []
    if args.audit:
        from repro.lint.auditor import run_audit

        audit = run_audit()

    report = Report(findings=findings, audit=audit)
    if args.out:
        with open(args.out, "w", encoding="utf-8") as fh:
            fh.write(report.to_json(list(args.paths)))
    if args.format == "json":
        print(report.to_json(list(args.paths)))
    else:
        print(report.render_text())

    if report.errors or report.audit_failures:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
