"""Internal link/anchor checker for the repo's markdown docs.

Verifies that every relative link in the given markdown files points at
an existing file, and that every ``#anchor`` fragment resolves to a
heading in the target file under GitHub's slugification (lowercase,
punctuation stripped, spaces to hyphens — the rule that turns
``## §9 Statistical inference: ...`` into ``#9-statistical-inference-...``).
External (http/https) links are not fetched. Also verifies the
DESIGN.md §10 rule-ID table stays in sync with the registered rules in
``repro.lint.catalog`` (a stdlib-only import — no jax needed).

  python scripts/check_docs.py README.md DESIGN.md

Exits non-zero listing every broken link. Run by the CI docs job.
"""
from __future__ import annotations

import functools
import re
import sys
from collections import Counter
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

LINK_RE = re.compile(r"(?<!!)\[[^\]]+\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
FENCE_RE = re.compile(r"^(```|~~~)")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase; drop everything that is not a
    word character, space, or hyphen; spaces become hyphens."""
    slug = heading.strip().lower()
    slug = re.sub(r"[^\w\- ]", "", slug, flags=re.UNICODE)
    return slug.replace(" ", "-")


def _strip_fences(text: str):
    """Yield (lineno, line) outside fenced code blocks."""
    in_fence = False
    for i, line in enumerate(text.splitlines(), 1):
        if FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if not in_fence:
            yield i, line


@functools.lru_cache(maxsize=None)
def anchors_of(path: Path) -> frozenset:
    """All valid GitHub anchors of a markdown file (with -1/-2 suffixes
    for duplicate headings). Cached: every anchored link into a file
    would otherwise re-parse it."""
    seen = Counter()
    out = set()
    for _, line in _strip_fences(path.read_text()):
        m = HEADING_RE.match(line)
        if not m:
            continue
        base = github_slug(m.group(2))
        n = seen[base]
        out.add(base if n == 0 else f"{base}-{n}")
        seen[base] += 1
    return frozenset(out)


def check_file(md: Path, root: Path):
    errors = []
    for lineno, line in _strip_fences(md.read_text()):
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            dest = (md.parent / path_part).resolve() if path_part else md
            if path_part and not dest.exists():
                errors.append(f"{md.relative_to(root)}:{lineno}: "
                              f"missing file {target!r}")
                continue
            if anchor:
                if dest.suffix.lower() not in (".md", ".markdown"):
                    continue
                if anchor.lower() not in anchors_of(dest):
                    errors.append(f"{md.relative_to(root)}:{lineno}: "
                                  f"anchor {target!r} not found in "
                                  f"{dest.name}")
    return errors


def check_rule_table(design: Path):
    """DESIGN.md §10 table rows must match repro.lint.catalog exactly:
    every registered rule documented, no stale IDs, names in sync."""
    from repro.lint.catalog import AST_RULES, AUDIT_CHECKS

    registered = {r.id: r.name for r in AST_RULES + AUDIT_CHECKS}
    row_re = re.compile(r"^\|\s*(RL\d{3})\s*\|\s*([\w\-]+)\s*\|")
    documented = {}
    for _, line in _strip_fences(design.read_text()):
        m = row_re.match(line.strip())
        if m:
            documented[m.group(1)] = m.group(2)

    errors = []
    for rid, name in registered.items():
        if rid not in documented:
            errors.append(f"DESIGN.md §10: registered rule {rid} "
                          f"({name}) missing from the rule table")
        elif documented[rid] != name:
            errors.append(f"DESIGN.md §10: {rid} documented as "
                          f"{documented[rid]!r} but registered as {name!r}")
    for rid in documented:
        if rid not in registered:
            errors.append(f"DESIGN.md §10: table row {rid} has no "
                          f"registered rule in repro.lint.catalog")
    return errors


def check_metric_table(design: Path):
    """DESIGN.md §11 metric rows must match repro.obs.catalog exactly:
    every cataloged metric documented with its kind, no stale names."""
    from repro.obs.catalog import METRICS

    registered = {m.name: m.kind for m in METRICS}
    row_re = re.compile(r"^\|\s*`([\w.\-]+)`\s*\|\s*(\w+)\s*\|")
    documented = {}
    for _, line in _strip_fences(design.read_text()):
        m = row_re.match(line.strip())
        if m and "." in m.group(1):
            documented[m.group(1)] = m.group(2)

    errors = []
    for name, kind in registered.items():
        if name not in documented:
            errors.append(f"DESIGN.md §11: cataloged metric {name} "
                          f"missing from the metric table")
        elif documented[name] != kind:
            errors.append(f"DESIGN.md §11: {name} documented as kind "
                          f"{documented[name]!r} but cataloged as {kind!r}")
    for name in documented:
        if name not in registered:
            errors.append(f"DESIGN.md §11: table row {name} has no "
                          f"entry in repro.obs.catalog")
    return errors


def check_regime_table(design: Path):
    """DESIGN.md §14 regime matrix must match ``benchmarks.regimes``
    exactly (both directions): the table header enumerates every
    estimator cell of the benchmark grid, and the first column every
    attack. A regime added to the harness but not the table (or the
    reverse) is drift between the documented claim and what CI runs.
    Stdlib-only: the benchmark module's constants import without jax."""
    sys.path.insert(0, str(design.resolve().parent))
    from benchmarks.regimes import ATTACKS, ESTIMATOR_CELLS

    header = None
    attacks_doc = []
    for _, line in _strip_fences(design.read_text()):
        s = line.strip()
        if header is None:
            if s.startswith("|") and "Attack" in s and "`mean`" in s:
                header = re.findall(r"`([\w\-]+)`", s)
            continue
        if not s.startswith("|"):
            break
        first = re.match(r"^\|\s*`([\w\-]+)`\s*\|", s)
        if first:
            attacks_doc.append(first.group(1))

    errors = []
    if header is None:
        return ["DESIGN.md §14: regime matrix table not found "
                "(header row with backticked estimator cells)"]
    for est in ESTIMATOR_CELLS:
        if est not in header:
            errors.append(f"DESIGN.md §14: estimator cell {est!r} "
                          f"(benchmarks.regimes.ESTIMATOR_CELLS) missing "
                          f"from the regime table header")
    for est in header:
        if est not in ESTIMATOR_CELLS:
            errors.append(f"DESIGN.md §14: regime table header column "
                          f"{est!r} is not a benchmark estimator cell")
    for atk in ATTACKS:
        if atk not in attacks_doc:
            errors.append(f"DESIGN.md §14: attack {atk!r} "
                          f"(benchmarks.regimes.ATTACKS) missing from "
                          f"the regime table rows")
    for atk in attacks_doc:
        if atk not in ATTACKS:
            errors.append(f"DESIGN.md §14: regime table row {atk!r} is "
                          f"not a benchmark attack")
    return errors


def main(argv):
    root = Path(__file__).resolve().parent.parent
    files = [root / a for a in argv] if argv else [root / "README.md",
                                                   root / "DESIGN.md"]
    errors = []
    for md in files:
        if not md.exists():
            errors.append(f"missing doc file: {md}")
            continue
        errors.extend(check_file(md, root))
        print(f"checked {md.relative_to(root)}")
        if md.name == "DESIGN.md":
            errors.extend(check_rule_table(md))
            print("checked DESIGN.md §10 rule table against "
                  "repro.lint.catalog")
            errors.extend(check_metric_table(md))
            print("checked DESIGN.md §11 metric table against "
                  "repro.obs.catalog")
            errors.extend(check_regime_table(md))
            print("checked DESIGN.md §14 regime matrix against "
                  "benchmarks.regimes")
    if errors:
        print("\nBROKEN LINKS:")
        for e in errors:
            print(f"  {e}")
        return 1
    print("all internal links and anchors resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
