"""Multi-device tests for the robust aggregation + sharded train step.

These run in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest process keeps a single device (per the brief).
"""
import json
import os
import subprocess
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_robust_rrs_matches_ref():
    """shard_map all_to_all RRS == single-host reference aggregation."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.dist import robust_reduce as RR
from repro.kernels import ref as kref
mesh = jax.make_mesh((4, 2), ("data", "model"))
key = jax.random.PRNGKey(0)
grads = {
  "a": {"w_gate": jax.random.normal(key, (4, 6, 16))},   # model-sharded dim 2
  "b": jax.random.normal(jax.random.PRNGKey(1), (4, 7)),
}
sh = {"a": {"w_gate": NamedSharding(mesh, P("data", None, "model"))},
      "b": NamedSharding(mesh, P("data", None))}
grads_p = jax.tree.map(jax.device_put, grads, sh)
agg = jax.jit(lambda g: RR.aggregate_stacked_rrs(g, mesh, ("data",), "vrmom"))(grads_p)
want_a = kref.ref_vrmom(grads["a"]["w_gate"].reshape(4, -1), K=10).reshape(6, 16)
# RRS flattens+concats all leaves then chunks by worker; per-coordinate
# results must match the per-leaf reference exactly (coordinate-wise op).
np.testing.assert_allclose(np.asarray(agg["a"]["w_gate"]), np.asarray(want_a), rtol=2e-5, atol=2e-5)
want_b = kref.ref_vrmom(grads["b"].reshape(4, -1), K=10).reshape(7)
np.testing.assert_allclose(np.asarray(agg["b"]), np.asarray(want_b), rtol=2e-5, atol=2e-5)
print("RRS-OK")
""")
    assert "RRS-OK" in out


def test_train_step_robust_vs_byzantine():
    """End-to-end sharded training: VRMOM survives a Byzantine worker,
    mean aggregation does not."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get as get_arch
from repro.data import lm_batch, shard_batch
from repro.models import model as M
from repro.train.step import make_train_step
import repro.optim as O
from repro.dist import sharding as S

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_arch("qwen3-1.7b").reduced()
params = M.init(jax.random.PRNGKey(0), cfg)

def run(mode, aggregator, byz):
    setup = make_train_step(cfg, mesh, estimator=aggregator, mode=mode,
                            byzantine_frac=byz, attack="omniscient", lr=1e-2)
    opt = O.get(cfg.optimizer, lr=1e-2)
    p = jax.device_put(params, S.to_named(mesh, setup.params_specs))
    st = jax.jit(opt.init)(p)
    step = jax.jit(setup.step_fn)
    losses = []
    for i in range(8):
        b = shard_batch(lm_batch(cfg, i, 8, 32), mesh, setup.batch_axes)
        p, st, loss = step(p, st, b, jax.random.PRNGKey(i))
        losses.append(float(loss))
    return losses, p

l_clean, _ = run("stacked-rrs", "vrmom", 0.0)
assert l_clean[-1] < l_clean[0], (l_clean[0], l_clean[-1])

l_byz, p_byz = run("stacked-rrs", "vrmom", 0.4)
assert np.isfinite(l_byz).all()
gn = float(jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32)**2) for x in jax.tree.leaves(p_byz))))
assert np.isfinite(gn)
# VRMOM keeps training stable under the omniscient attack
assert l_byz[-1] < l_byz[0] + 0.3

l_mean, p_mean = run("stacked-rrs", "mean", 0.4)
# Mean aggregation diverges under the same attack (AdamW bounds the
# update magnitude, so the signature is steady loss increase, not NaN).
assert (not np.isfinite(l_mean[-1])) or l_mean[-1] > l_mean[0] + 1.0
assert (not np.isfinite(l_mean[-1])) or l_mean[-1] > l_byz[-1] + 1.0
print("TRAIN-OK", l_clean[-1], l_byz[-1])
""", timeout=1800)
    assert "TRAIN-OK" in out


def test_stacked_auto_equals_rrs():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.dist import robust_reduce as RR
mesh = jax.make_mesh((8, 1), ("data", "model"))
g = {"w_up": jax.random.normal(jax.random.PRNGKey(2), (8, 12, 8))}
sh = {"w_up": NamedSharding(mesh, P("data", None, "model"))}
gp = jax.tree.map(jax.device_put, g, sh)
a = jax.jit(lambda x: RR.aggregate_stacked_auto(x, "vrmom"))(gp)
b = jax.jit(lambda x: RR.aggregate_stacked_rrs(x, mesh, ("data",), "vrmom"))(gp)
np.testing.assert_allclose(np.asarray(a["w_up"]), np.asarray(b["w_up"]), rtol=2e-5, atol=2e-5)
print("AUTO-EQ-RRS")
""")
    assert "AUTO-EQ-RRS" in out


def test_inloop_robust_dot():
    """IB-RRS: robust_dot gradient equals stacked VRMOM of per-worker dW."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.dist import robust_reduce as RR
from repro.kernels import ref as kref
mesh = jax.make_mesh((4, 2), ("data", "model"))
W = 4
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (8, 6, 10))          # batch 8 = 4 workers x 2
w = jax.random.normal(jax.random.PRNGKey(1), (10, 12))
dy = jax.random.normal(jax.random.PRNGKey(2), (8, 6, 12))
xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
dys = jax.device_put(dy, NamedSharding(mesh, P("data", None, None)))

def f(x, w):
    with RR.robust_backward(mesh, ("data",), "vrmom"):
        y = RR.robust_dot(x, w)
    return jnp.sum(y * dy)

dw = jax.jit(jax.grad(f, argnums=1))(xs, w)
# reference: per-worker dW then VRMOM over workers
xw = x.reshape(W, 2, 6, 10); dyw = dy.reshape(W, 2, 6, 12)
dws = jnp.einsum('wbsd,wbsf->wdf', xw, dyw)
want = kref.ref_vrmom(dws.reshape(W, -1), K=10).reshape(10, 12)
np.testing.assert_allclose(np.asarray(dw), np.asarray(want), rtol=1e-4, atol=1e-4)
print("INLOOP-OK")
""")
    assert "INLOOP-OK" in out


def test_production_mesh_construction():
    out = _run("""
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
m2 = make_production_mesh(multi_pod=True)
assert dict(m1.shape) == {"data": 16, "model": 16}
assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
print("MESH-OK")
""", devices=512)
    assert "MESH-OK" in out


def test_multipod_worker_axes_aggregation():
    """pod x data worker axes (2x2x2 mesh): RRS over ('pod','data')."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.dist import robust_reduce as RR
from repro.kernels import ref as kref
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
W = 4
g = {"w_up": jax.random.normal(jax.random.PRNGKey(0), (W, 8, 16))}
sh = {"w_up": NamedSharding(mesh, P(("pod", "data"), None, "model"))}
gp = jax.tree.map(jax.device_put, g, sh)
agg = jax.jit(lambda x: RR.aggregate_stacked_rrs(
    x, mesh, ("pod", "data"), "vrmom"))(gp)
want = kref.ref_vrmom(g["w_up"].reshape(W, -1), K=10).reshape(8, 16)
np.testing.assert_allclose(np.asarray(agg["w_up"]), np.asarray(want),
                           rtol=2e-5, atol=2e-5)
print("MULTIPOD-OK")
""")
    assert "MULTIPOD-OK" in out


def test_train_step_on_pod_mesh():
    """Full train step on a (pod,data,model) mesh — the multi-pod path."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get as get_arch
from repro.data import lm_batch, shard_batch
from repro.models import model as M
from repro.train.step import make_train_step
import repro.optim as O
from repro.dist import sharding as S
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_arch("mamba2-2.7b").reduced()
setup = make_train_step(cfg, mesh, byzantine_frac=0.3, attack="gaussian",
                        lr=1e-2, microbatch=1)
assert setup.n_workers == 4 and setup.worker_axes == ("pod", "data")
opt = O.get(cfg.optimizer, lr=1e-2)
params = M.init(jax.random.PRNGKey(0), cfg)
p = jax.device_put(params, S.to_named(mesh, setup.params_specs))
st = jax.jit(opt.init)(p)
step = jax.jit(setup.step_fn)
for i in range(3):
    b = shard_batch(lm_batch(cfg, i, 8, 32), mesh, setup.batch_axes)
    p, st, loss = step(p, st, b, jax.random.PRNGKey(i))
    assert np.isfinite(float(loss))
print("POD-TRAIN-OK", float(loss))
""", timeout=1200)
    assert "POD-TRAIN-OK" in out
