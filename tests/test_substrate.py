"""Unit tests for the substrate layers: optimizers, data, checkpoint,
sharding rules, configs/input_specs, hlo_cost."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.optim as O
from repro import checkpoint as CKPT
from repro.configs import ARCHS, INPUT_SHAPES, get as get_arch, input_specs
from repro.data import lm_batch
from repro.dist import sharding as S
from repro.launch import hlo_cost


# ---------------------------------------------------------------- optimizers

def _quad_params():
    return {"a": jnp.asarray([3.0, -2.0]), "b": {"w": jnp.asarray([[1.5]])}}


@pytest.mark.parametrize("name,kw", [
    ("sgd", {"lr": 0.1}), ("sgd", {"lr": 0.1, "momentum": 0.9}),
    ("adamw", {"lr": 0.2}), ("adafactor", {"lr": 0.5}),
])
def test_optimizers_minimize_quadratic(name, kw):
    opt = O.get(name, **kw)
    params = _quad_params()
    state = opt.init(params)

    def loss(p):
        return sum(jnp.sum(x**2) for x in jax.tree.leaves(p))

    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss(params)) < 0.05 * float(loss(_quad_params()))


def test_adafactor_state_is_factored():
    opt = O.get("adafactor")
    params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}
    st = opt.init(params)
    assert st["v"]["w"]["vr"].shape == (8,)
    assert st["v"]["w"]["vc"].shape == (16,)
    assert st["v"]["b"]["v"].shape == (16,)
    # bf16 momentum (the llama3-405b HBM fit, DESIGN.md §5)
    assert st["m"]["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------- data

def test_lm_batch_deterministic_and_learnable():
    cfg = get_arch("qwen3-1.7b").reduced()
    b1 = lm_batch(cfg, 3, 4, 32)
    b2 = lm_batch(cfg, 3, 4, 32)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = lm_batch(cfg, 4, 4, 32)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    assert int(b1["tokens"].max()) < cfg.vocab


def test_modality_stubs_in_batch():
    enc = get_arch("whisper-medium").reduced()
    b = lm_batch(enc, 0, 2, 16)
    assert b["frames"].shape == (2, enc.encoder.n_frames, enc.d_model)
    vlm = get_arch("phi-3-vision-4.2b").reduced()
    b = lm_batch(vlm, 0, 2, 16)
    assert b["patches"].shape == (2, vlm.vision.n_patches, vlm.d_model)
    assert b["tokens"].shape[1] == 16 - vlm.vision.n_patches


# ---------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip():
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.asarray([1, 2], jnp.int32)},
            "scalar": jnp.asarray(2.5, jnp.bfloat16)}
    with tempfile.TemporaryDirectory() as d:
        CKPT.save(d, tree)
        out = CKPT.restore(d, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------- configs

def test_all_archs_registered_with_exact_dims():
    assert len(ARCHS) == 10
    c = get_arch("llama3-405b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (126, 16384, 128, 8, 53248, 128256)
    c = get_arch("granite-moe-3b-a800m")
    assert c.moe.n_experts == 40 and c.moe.top_k == 8 and c.d_ff == 512
    c = get_arch("mamba2-2.7b")
    assert c.ssm.d_state == 128 and c.family == "ssm"
    c = get_arch("zamba2-7b")
    assert c.n_layers == 81 and c.ssm.d_state == 64 and c.hybrid_attn_every == 6
    c = get_arch("mixtral-8x7b")
    assert c.sliding_window == 4096 and c.moe.top_k == 2


def test_input_specs_all_combos_shape_only():
    for arch in ARCHS.values():
        for shape in INPUT_SHAPES.values():
            specs = input_specs(arch, shape)
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)
            if shape.kind == "decode":
                assert specs["token"].shape == (shape.global_batch,)
            else:
                assert specs["tokens"].shape[0] == shape.global_batch


def test_reduced_configs_are_small():
    for arch in ARCHS.values():
        r = arch.reduced()
        assert r.n_layers <= 4 and r.d_model <= 256 and r.vocab <= 512
        if r.moe:
            assert r.moe.n_experts <= 4


# ---------------------------------------------------------------- sharding

def test_param_spec_rules_divisibility():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    shapes = {
        "embed": jax.ShapeDtypeStruct((51865, 1024), jnp.bfloat16),
        "layers": {"attn": {
            "wq": jax.ShapeDtypeStruct((32, 4608, 36, 128), jnp.bfloat16),
            "wk": jax.ShapeDtypeStruct((32, 4608, 4, 128), jnp.bfloat16),
        }},
    }
    specs = S.param_specs(shapes, FakeMesh())
    # 51865 vocab not divisible by 16 -> 'model' dropped or moved to 1024
    emb = specs["embed"]
    assert emb[0] != "model"
    # 36 heads: replicated, NOT moved to head_dim (score all-reduce trap)
    wq = specs["layers"]["attn"]["wq"]
    assert wq[2] is None and wq[3] is None
    assert wq[1] == "data"


def test_batch_axes_for():
    class M3:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    class M2:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    assert S.batch_axes_for(M3(), 256) == ("pod", "data")
    assert S.batch_axes_for(M3(), 16) == ("data",)
    assert S.batch_axes_for(M3(), 3) is None
    assert S.batch_axes_for(M2(), 32) == ("data",)


def test_opt_state_specs_adafactor_factored():
    """adafactor's factored vr/vc leaves inherit the parent param spec
    minus the reduced dim (vr = spec[:-1], vc = spec minus dim -2)."""
    from jax.sharding import PartitionSpec as P

    params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((4,))}
    params_specs = {"w": P("data", "model"), "b": P(None)}
    opt = O.get("adafactor")
    opt_shapes = jax.eval_shape(opt.init, params)
    specs = S.opt_state_specs(opt_shapes, params, params_specs)
    assert specs["v"]["w"]["vr"] == P("data")
    assert specs["v"]["w"]["vc"] == P("model")
    assert specs["v"]["b"]["v"] == P(None)
    # bf16 momentum mirrors the param tree spec exactly
    assert specs["m"]["w"] == P("data", "model")
    assert specs["m"]["b"] == P(None)


# ---------------------------------------------------------------- hlo_cost

def test_hlo_cost_scan_trip_multiplication():
    n = 128
    w = jnp.zeros((n, n))
    x = jnp.zeros((n, n))

    def f(w, x):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    comp = jax.jit(f).lower(w, x).compile()
    res = hlo_cost.analyze(comp.as_text())
    assert res["flops"] == pytest.approx(7 * 2 * n**3, rel=0.01)


def test_hlo_cost_nested_scans():
    n = 64
    w = jnp.zeros((n, n))
    x = jnp.zeros((n, n))

    def f(w, x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    comp = jax.jit(f).lower(w, x).compile()
    res = hlo_cost.analyze(comp.as_text())
    assert res["flops"] == pytest.approx(15 * 2 * n**3, rel=0.01)
