"""Hypothesis property-based tests for the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the [test] extra (pip install -e .[test])")
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import vrmom as V

_settings = settings(max_examples=40, deadline=None)


def _xbars(min_m=5, max_m=64):
    return hnp.arrays(
        dtype=np.float64,
        shape=st.integers(min_m, max_m),
        elements=st.floats(-1e3, 1e3, allow_nan=False, width=64),
    )


@_settings
@given(_xbars(), st.integers(1, 20))
def test_permutation_invariance(x, K):
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(x))
    a = float(V.vrmom(jnp.asarray(x, jnp.float32), K=K))
    b = float(V.vrmom(jnp.asarray(x[perm], jnp.float32), K=K))
    assert np.isclose(a, b, rtol=1e-4, atol=1e-4)


@_settings
@given(_xbars(), st.floats(0.1, 10.0), st.floats(-100.0, 100.0))
def test_affine_equivariance(x, a, b):
    x32 = jnp.asarray(x, jnp.float32)
    lhs = float(V.vrmom(a * x32 + b, K=10))
    rhs = a * float(V.vrmom(x32, K=10)) + b
    tol = 1e-3 * max(1.0, abs(rhs))
    assert abs(lhs - rhs) <= tol


@_settings
@given(_xbars(), st.integers(1, 30))
def test_bounded_influence_vs_median(x, K):
    """Remark 2: |vrmom - mom| <= s * K/2 / sum_k psi(Delta_k)."""
    x32 = jnp.asarray(x, jnp.float32)
    med = float(V.mom(x32))
    s = float(V.mad_scale(x32))
    est = float(V.vrmom(x32, K=K))
    bound = s * V.vrmom_correction_bound(K) + 1e-4 * (1 + abs(med))
    assert abs(est - med) <= bound


@_settings
@given(st.floats(-1e3, 1e3, allow_nan=False), st.integers(5, 40))
def test_constant_inputs_exact(c, m):
    x = jnp.full((m,), np.float32(c))
    assert np.isclose(float(V.vrmom(x)), np.float32(c), rtol=1e-5, atol=1e-5)


@_settings
@given(_xbars(min_m=9), st.integers(1, 15))
def test_minority_corruption_bounded(x, K):
    """Corrupting < half of the workers moves the estimate by O(s + quantile gap)."""
    x = np.sort(x)
    m = len(x)
    n_byz = (m - 1) // 2 - 1
    if n_byz < 1:
        return
    y = x.copy()
    y[-n_byz:] = 1e12  # adversarial blow-up
    a = float(V.vrmom(jnp.asarray(x, jnp.float32), K=K))
    b = float(V.vrmom(jnp.asarray(y, jnp.float32), K=K))
    # Honest spread bounds how far the estimate can be dragged.
    spread = x.max() - x.min() + 1e-3
    assert abs(b - a) <= 4.0 * spread * (1.0 + V.vrmom_correction_bound(K))
