"""Tests for the plug-in inference layer (repro.infer, DESIGN.md §9)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks, rcsl as R, vrmom as V
from repro.core.estimator import Estimator
from repro.dist.robust_reduce import aggregate_symmetric_stacked
from repro.infer import (bvn_cdf, confidence_intervals,
                         contamination_inflation, corrupt_stats, cov_factor,
                         coverage_run, infer, machine_stats, mom_cov_factor,
                         robust_moments, sandwich_cov, vrmom_cov_factor)


# ---------------------------------------------------------------------------
# The jittable Theorem-4 machinery vs its host-side numpy oracles
# ---------------------------------------------------------------------------


def test_bvn_cdf_matches_host_quadrature():
    cases = [(0.5, -0.3, 0.6), (0.0, 0.0, 0.3), (1.2, 1.2, -0.8),
             (-1.0, 2.0, 0.95), (0.3, -0.7, 0.0)]
    for a, b, rho in cases:
        host = V._phi2_cdf_grid(a, b, rho)
        assert float(bvn_cdf(a, b, rho)) == pytest.approx(host, abs=2e-4)


def test_bvn_cdf_special_values():
    from jax.scipy.special import ndtr

    # independence: P = Phi(a) Phi(b)
    got = float(bvn_cdf(0.7, -0.2, 0.0))
    assert got == pytest.approx(float(ndtr(0.7) * ndtr(-0.2)), abs=1e-6)
    # the arcsine law at the origin
    rho = 0.37
    assert float(bvn_cdf(0.0, 0.0, rho)) == pytest.approx(
        0.25 + math.asin(rho) / (2 * math.pi), abs=1e-6)
    # perfect correlation collapses to the marginals (hit by every
    # correlation-matrix diagonal)
    assert float(bvn_cdf(0.7, 1.5, 1.0)) == pytest.approx(
        float(ndtr(0.7)), abs=1e-6)
    assert float(bvn_cdf(0.5, -0.5, -1.0)) == pytest.approx(
        float(ndtr(0.5) + ndtr(-0.5) - 1.0), abs=1e-6)


def test_vrmom_cov_factor_matches_host_oracle():
    rng = np.random.default_rng(0)
    A = rng.standard_normal((3, 3))
    Sigma = A @ A.T + 0.5 * np.eye(3)
    C_host = V.vrmom_asymptotic_cov(Sigma, K=10)
    C = np.asarray(vrmom_cov_factor(jnp.asarray(Sigma), K=10))
    np.testing.assert_allclose(C, C_host, rtol=2e-3, atol=1e-4)
    # diagonal recovers the 1-D theory: C_ll = sigma_K^2 Sigma_ll
    np.testing.assert_allclose(np.diag(C), V.sigma_k_sq(10) * np.diag(Sigma),
                               rtol=1e-4)


def test_mom_cov_factor_closed_form():
    rng = np.random.default_rng(1)
    A = rng.standard_normal((3, 3))
    Sigma = A @ A.T + 0.5 * np.eye(3)
    C_host = V.mom_asymptotic_cov(Sigma)
    C = np.asarray(mom_cov_factor(jnp.asarray(Sigma)))
    np.testing.assert_allclose(C, C_host, rtol=2e-3, atol=1e-4)
    np.testing.assert_allclose(np.diag(C), (math.pi / 2) * np.diag(Sigma),
                               rtol=1e-5)


def test_cov_factor_dispatch_and_rejection():
    Sigma = jnp.eye(2)
    np.testing.assert_allclose(
        np.asarray(cov_factor(Sigma, Estimator(method="mean"))),
        np.eye(2), atol=1e-7)
    assert float(cov_factor(Sigma, Estimator(method="median"))[0, 0]) == \
        pytest.approx(math.pi / 2, rel=1e-5)
    # trimmed_mean carries the winsorized-IF scaling (>= 1 on the
    # diagonal — trimming always costs efficiency at the Gaussian)
    tm = np.asarray(cov_factor(Sigma, Estimator(method="trimmed_mean",
                                                beta=0.2)))
    assert tm[0, 0] > 1.0
    # whole-vector selectors have no normality theory in the paper
    with pytest.raises(ValueError, match="no asymptotic-normality"):
        cov_factor(Sigma, Estimator(method="geometric_median"))


def test_contamination_inflation():
    assert contamination_inflation(0.0) == 1.0
    assert contamination_inflation(0.0, "median") == 1.0
    # exact rank-offset result for the median
    assert contamination_inflation(0.1, "median") == pytest.approx(
        1.0 / 0.81, rel=1e-9)
    # VRMOM pays more than MOM for contamination (its correction term
    # has its own garbage influence), and inflation grows with alpha
    assert contamination_inflation(0.1) > contamination_inflation(0.1, "median")
    assert contamination_inflation(0.2) > contamination_inflation(0.1) > 1.0
    with pytest.raises(ValueError):
        contamination_inflation(0.5)


# ---------------------------------------------------------------------------
# Symmetric-stack aggregation (dist wire format)
# ---------------------------------------------------------------------------


def test_aggregate_symmetric_stacked_exact_and_robust():
    key = jax.random.PRNGKey(0)
    W, p = 15, 4
    A = jax.random.normal(key, (W, p, p))
    mats = A + jnp.swapaxes(A, -1, -2)  # symmetric stack
    out = aggregate_symmetric_stacked(mats, "median")
    # exactly symmetric, and equal to per-coordinate aggregation
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out.T))
    full = Estimator(method="median", backend="jnp").apply(mats, axis=0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full), atol=1e-6)
    # corrupted rows cannot move the median aggregate far
    bad = mats.at[-7:].set(1e6)
    out_bad = aggregate_symmetric_stacked(bad, "median")
    assert float(jnp.max(jnp.abs(out_bad - out))) < 5.0


def test_aggregate_symmetric_stacked_rejects_bad_inputs():
    with pytest.raises(ValueError, match="symmetric stack"):
        aggregate_symmetric_stacked(jnp.zeros((5, 3, 4)), "median")
    with pytest.raises(ValueError, match="whole-vector"):
        aggregate_symmetric_stacked(jnp.zeros((5, 3, 3)), "krum")


def test_wrong_value_attack():
    v = jnp.zeros((6, 3))
    mask = attacks.byzantine_mask(6, 0.4)  # 2 corrupted rows
    out = attacks.get("wrong_value")(jax.random.PRNGKey(0), v, mask)
    np.testing.assert_allclose(np.asarray(out[:4]), 0.0)
    np.testing.assert_allclose(np.asarray(out[4:]), 100.0)


# ---------------------------------------------------------------------------
# Sandwich covariance against textbook theory
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def lin_setup():
    p = 4
    theta_star = R.paper_theta_star(p)
    shards = R.make_shards(jax.random.PRNGKey(0), N_per_machine=400,
                           m_workers=40, p=p, theta_star=theta_star,
                           model="linear")
    prob = R.LinearRegressionProblem()
    theta_hat, _ = R.rcsl(prob, shards, jax.random.PRNGKey(1), rounds=5)
    return prob, shards, theta_star, theta_hat


def test_sandwich_matches_ols_theory(lin_setup):
    """With mean aggregation the sandwich collapses to the OLS covariance
    sigma^2 Sigma_x^{-1} (H = 2 Sigma, Sigma_g = 4 sigma^2 Sigma)."""
    prob, shards, theta_star, theta_hat = lin_setup
    stats = machine_stats(prob, theta_hat, shards)
    H, Sig = robust_moments(stats, "mean")
    Xi = sandwich_cov(H, Sig, "mean")
    p = theta_star.shape[0]
    idx = jnp.arange(p)
    Sigma_x = 0.5 ** jnp.abs(idx[:, None] - idx[None, :])  # make_shards rho
    Xi_theory = jnp.linalg.inv(Sigma_x)  # noise_std = 1
    np.testing.assert_allclose(np.asarray(Xi), np.asarray(Xi_theory),
                               rtol=0.2, atol=0.05)


def test_vrmom_interval_efficiency(lin_setup):
    """VRMOM CIs are narrower than MOM CIs on the same data (Theorem 1's
    efficiency gain surfacing in interval width), wider than mean CIs."""
    prob, shards, theta_star, theta_hat = lin_setup
    widths = {}
    for est in ("mean", "vrmom", "median"):
        res = infer(prob, shards, theta_hat, estimator=est)
        widths[est] = float(jnp.mean(res.ci.upper - res.ci.lower))
    assert widths["mean"] < widths["vrmom"] < widths["median"]
    # the asymptotic ratio is sqrt(sigma_K^2 / (pi/2)) ~ 0.82 at K=10;
    # at m=41 machines the two plug-in Sigma_hats differ too, so only
    # bracket it (the coverage benchmark pins the calibrated behaviour)
    assert 0.6 < widths["vrmom"] / widths["median"] < 0.92


def test_ci_width_shrinks_like_sqrt_n():
    p = 3
    theta_star = R.paper_theta_star(p)
    prob = R.LinearRegressionProblem()
    widths = []
    for n in (200, 800):  # 4x the data -> half the width
        shards = R.make_shards(jax.random.PRNGKey(2), N_per_machine=n,
                               m_workers=30, p=p, theta_star=theta_star,
                               model="linear")
        theta_hat, _ = R.rcsl(prob, shards, jax.random.PRNGKey(3), rounds=5)
        res = infer(prob, shards, theta_hat)
        widths.append(float(jnp.mean(res.ci.upper - res.ci.lower)))
    assert widths[0] / widths[1] == pytest.approx(2.0, rel=0.1)


def test_ci_width_grows_with_level_and_alpha(lin_setup):
    prob, shards, theta_star, theta_hat = lin_setup
    w = {lvl: float(jnp.mean(
        (r := infer(prob, shards, theta_hat, level=lvl)).ci.upper
        - r.ci.lower)) for lvl in (0.8, 0.95, 0.99)}
    assert w[0.8] < w[0.95] < w[0.99]
    # assumed Byzantine fraction widens the interval (finite-alpha
    # contamination inflation), deterministically
    wa = {a: float(jnp.mean(
        (r := infer(prob, shards, theta_hat, alpha=a)).ci.upper
        - r.ci.lower)) for a in (0.0, 0.1, 0.2)}
    assert wa[0.0] < wa[0.1] < wa[0.2]
    assert wa[0.1] / wa[0.0] == pytest.approx(
        math.sqrt(contamination_inflation(0.1)), rel=1e-4)


def test_simultaneous_wider_than_pointwise(lin_setup):
    prob, shards, theta_star, theta_hat = lin_setup
    res_pt = infer(prob, shards, theta_hat)
    res_si = infer(prob, shards, theta_hat, simultaneous=True)
    assert bool(jnp.all(res_si.ci.lower < res_pt.ci.lower))
    assert bool(jnp.all(res_si.ci.upper > res_pt.ci.upper))


def test_ci_attack_invariance(lin_setup):
    """floor(alpha*m) machines reporting garbage statistics must not move
    the robustly-aggregated CI: same centre, nearly the same width as
    the honestly-computed CI at the same assumed alpha."""
    prob, shards, theta_star, theta_hat = lin_setup
    clean = infer(prob, shards, theta_hat, alpha=0.2)  # attack='none'
    for attack in ("gaussian", "signflip", "wrong_value"):
        res = infer(prob, shards, theta_hat, alpha=0.2, attack=attack,
                    key=jax.random.PRNGKey(7))
        np.testing.assert_array_equal(np.asarray(res.theta),
                                      np.asarray(clean.theta))
        ratio = np.asarray(res.ci.se / clean.ci.se)
        assert np.all(ratio > 0.75) and np.all(ratio < 1.35), (attack, ratio)
    # a non-robust aggregate is destroyed by the same corruption: the
    # mean-aggregated H/Sigma absorb the garbage rows (H can even lose
    # positive-definiteness), so the resulting "CI" deviates wildly
    # where the robust one stayed put
    honest_mean = infer(prob, shards, theta_hat, estimator="mean")
    broken = infer(prob, shards, theta_hat, estimator="mean", alpha=0.2,
                   attack="gaussian", key=jax.random.PRNGKey(7))
    log_dev = np.abs(np.log(np.asarray(broken.ci.se)
                            / np.asarray(honest_mean.ci.se)))
    assert float(log_dev.max()) > math.log(1.5)


def test_infer_jits_and_matches_eager(lin_setup):
    prob, shards, theta_hat = lin_setup[0], lin_setup[1], lin_setup[3]
    eager = infer(prob, shards, theta_hat, alpha=0.1, attack="gaussian",
                  key=jax.random.PRNGKey(9))
    jitted = jax.jit(lambda s, t, k: infer(prob, s, t, alpha=0.1,
                                           attack="gaussian", key=k))(
        shards, theta_hat, jax.random.PRNGKey(9))
    np.testing.assert_allclose(np.asarray(eager.ci.lower),
                               np.asarray(jitted.ci.lower), rtol=2e-5,
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(eager.cov),
                               np.asarray(jitted.cov), rtol=2e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# Coverage harness
# ---------------------------------------------------------------------------


def test_coverage_close_to_nominal_small_rep():
    """Empirical coverage of the 95% CIs under the paper's Gaussian
    attack at alpha=0.1 — a small-rep version of the committed
    BENCH_inference.json acceptance cell (binomial noise at 40 reps
    demands loose bounds; the benchmark tightens them at 200)."""
    s = coverage_run(model="linear", attack="gaussian", alpha=0.1,
                     estimator="vrmom", reps=40, N_per_machine=200,
                     m_workers=100, p=5, rounds=6, level=0.95,
                     batch_size=10).summary()
    assert 0.85 <= s["coverage"] <= 1.0
    assert np.isfinite(s["mean_width"]) and s["mean_width"] > 0
    assert s["rmse"] < 0.05


def test_coverage_outputs_shapes():
    cell = coverage_run(model="linear", attack="none", alpha=0.0,
                        estimator="vrmom", reps=6, N_per_machine=100,
                        m_workers=20, p=3, rounds=3, batch_size=3)
    assert cell.covered.shape == (6, 3)
    assert cell.width.shape == (6, 3)
    assert cell.covered.dtype == jnp.bool_
    s = cell.summary()
    assert s["reps"] == 6 and len(s["coverage_per_coord"]) == 3


def test_coverage_rejects_indivisible_mesh_reps():
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >1 device")
    mesh = jax.make_mesh((len(devs),), ("data",))
    with pytest.raises(ValueError, match="not divisible"):
        coverage_run(reps=len(devs) + 1, mesh=mesh)
