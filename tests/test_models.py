"""Model-zoo correctness: SSD oracle, prefill/decode consistency, MoE mass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get as get_arch
from repro.models import mamba2 as M
from repro.models import model as Mo


def test_ssd_matches_naive_recurrence():
    """Chunked SSD == naive per-step recurrence (the defining property)."""
    key = jax.random.PRNGKey(0)
    b, S, H, P, G, N = 2, 37, 4, 8, 2, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (b, S, G, N)) * 0.5
    C = jax.random.normal(ks[4], (b, S, G, N)) * 0.5

    y_chunk, hT = M.ssd_chunked(x, dt, A, B, C, chunk=8)

    # naive recurrence
    h = jnp.zeros((b, H, P, N))
    ys = []
    for t in range(S):
        y_t, h = M.ssd_decode_step(x[:, t], dt[:, t], A, B[:, t], C[:, t], h)
        ys.append(y_t)
    y_naive = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunk_invariance():
    key = jax.random.PRNGKey(1)
    b, S, H, P, G, N = 1, 48, 2, 4, 1, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (b, S, G, N)) * 0.5
    C = jax.random.normal(ks[4], (b, S, G, N)) * 0.5
    y1, h1 = M.ssd_chunked(x, dt, A, B, C, chunk=6)
    y2, h2 = M.ssd_chunked(x, dt, A, B, C, chunk=48)
    y3, h3 = M.ssd_chunked(x, dt, A, B, C, chunk=7)  # non-divisible
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y3), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h3), rtol=1e-4, atol=1e-4)


ARCH_IDS = ["whisper-medium", "qwen3-1.7b", "starcoder2-7b",
            "phi-3-vision-4.2b", "zamba2-7b", "granite-moe-3b-a800m",
            "minitron-4b", "mamba2-2.7b", "mixtral-8x7b", "llama3-405b"]


def _smoke_batch(cfg, key, B=2, S=32):
    ks = jax.random.split(key, 2)
    batch = {}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[0], (B, cfg.encoder.n_frames, cfg.d_model), jnp.float32)
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
    elif cfg.family == "vlm":
        n = cfg.vision.n_patches
        batch["patches"] = jax.random.normal(ks[0], (B, n, cfg.d_model),
                                             jnp.float32)
        batch["tokens"] = jax.random.randint(ks[1], (B, S - n), 0, cfg.vocab)
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced variant of each assigned arch: one forward + one grad step."""
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(42)
    params = Mo.init(key, cfg)
    batch = _smoke_batch(cfg, key)
    loss, grads = jax.value_and_grad(lambda p: Mo.loss(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    logits, caches = Mo.prefill(params, cfg, batch)
    assert logits.shape[0] == batch["tokens"].shape[0]
    assert logits.shape[-1] == cfg.vocab
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mixtral-8x7b", "mamba2-2.7b",
                                  "zamba2-7b", "whisper-medium",
                                  "granite-moe-3b-a800m"])
def test_prefill_decode_consistency(arch):
    """decode_step after prefill reproduces the full-forward logits."""
    cfg = get_arch(arch).reduced()
    if cfg.moe is not None:
        # Token-dropping MoE is only prefill/decode-consistent when capacity
        # never binds (decode routes one token with fresh capacity).
        import dataclasses
        from repro.configs import MoEConfig
        cfg = dataclasses.replace(
            cfg, moe=MoEConfig(cfg.moe.n_experts, cfg.moe.top_k,
                               capacity_factor=float(cfg.moe.n_experts)))
    key = jax.random.PRNGKey(7)
    params = Mo.init(key, cfg)
    B, S = 2, 24
    batch = _smoke_batch(cfg, key, B=B, S=S)
    tokens = batch["tokens"]

    # full forward over S tokens
    logits_full, _ = Mo.prefill(params, cfg, batch)

    # prefill on S-1 tokens, then decode token S-1
    batch_p = dict(batch)
    batch_p["tokens"] = tokens[:, :-1]
    logits_pre, caches = Mo.prefill(params, cfg, batch_p, cache_len=S + 4)
    logits_dec, _ = Mo.decode_step(params, cfg, caches, tokens[:, -1])

    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full[:, -1], np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_moe_routing_mass_conservation():
    from repro.models import moe as X
    cfg = get_arch("mixtral-8x7b").reduced()
    key = jax.random.PRNGKey(3)
    p = X.moe_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, cfg.d_model))
    y, aux = X.moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(float(aux))
    # aux loss is >= 1 at uniform routing (Switch normalization)
    assert float(aux) > 0.5


def test_sliding_window_attention_masks():
    from repro.models.attention import mha
    key = jax.random.PRNGKey(5)
    B, S, H, dh = 1, 32, 2, 8
    q = jax.random.normal(key, (B, S, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(6), (B, S, H, dh))
    v = jax.random.normal(jax.random.PRNGKey(7), (B, S, H, dh))
    full = mha(q, k, v, causal=True, window=None, chunk=8)
    win = mha(q, k, v, causal=True, window=8, chunk=8)
    # early positions identical (window not yet binding at t < 8)
    np.testing.assert_allclose(np.asarray(full[:, :8]), np.asarray(win[:, :8]),
                               rtol=1e-5, atol=1e-5)
    # late positions differ (window binding)
    assert float(jnp.max(jnp.abs(full[:, -1] - win[:, -1]))) > 1e-4


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-2.7b"])
def test_multi_token_decode_matches_full_forward(arch):
    """Greedy 4-step decode == teacher-forced full forwards."""
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(11)
    params = Mo.init(key, cfg)
    B, S, T = 2, 12, 4
    tokens = jax.random.randint(key, (B, S + T), 0, cfg.vocab)

    batch_p = {"tokens": tokens[:, :S]}
    _, caches = Mo.prefill(params, cfg, batch_p, cache_len=S + T + 2)
    dec = []
    for t in range(T):
        logits, caches = Mo.decode_step(params, cfg, caches, tokens[:, S + t])
        dec.append(logits)

    full, _ = Mo.prefill(params, cfg, {"tokens": tokens})
    for t in range(T):
        np.testing.assert_allclose(
            np.asarray(dec[t], np.float32),
            np.asarray(full[:, S + t], np.float32), rtol=3e-3, atol=3e-3)


def test_prefill_last_only_matches_full():
    cfg = get_arch("qwen3-1.7b").reduced()
    params = Mo.init(jax.random.PRNGKey(1), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                          cfg.vocab)}
    full, _ = Mo.prefill(params, cfg, batch)
    last, _ = Mo.prefill(params, cfg, batch, last_only=True)
    np.testing.assert_allclose(np.asarray(last[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=1e-5, atol=1e-5)
