"""Tiny-rep smoke tests for benchmarks/paper_tables.py.

The table functions are the code behind ``examples/rcsl_regression.py``
and the paper's Section 4 reproduction; these tests run the *exact*
table code at toy sizes so a refactor of rcsl/infer cannot silently
break the table script between releases.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import paper_tables as T  # noqa: E402


def _check_rows(rows, expect_n):
    assert len(rows) == expect_n
    for name, a, b in rows:
        assert isinstance(name, str) and "/" in name
        assert np.isfinite(a), name
        assert np.isfinite(b), name


def test_table1_smoke():
    rows = T.table1(reps=2, m_workers=10, n=50, dims=(2,))
    _check_rows(rows, 4 * 4)  # K grid x alpha grid, one dim
    assert all(rmse >= 0 for _, rmse, _ in rows)


def test_table2_smoke():
    rows = T.table2(reps=2, m_workers=10, n=50, dims=(2,))
    _check_rows(rows, 2 * 4)
    # every vrmom row carries the ratio vs its mom row
    assert all(r > 0 for name, _, r in rows if name.endswith("/vrmom"))


def test_tables34_smoke():
    rows = T.tables34(reps=2, p=3, m_workers=10, n=60)
    _check_rows(rows, 2 + 3 * 3 * 2)
    assert all(r > 0 for _, _, r in rows)


def test_tables56_smoke():
    rows = T.tables56(reps=1, p=3, m_workers=10, n=80)
    _check_rows(rows, 2 * 4 * 2)


def test_table_coverage_smoke():
    rows = T.table_coverage(reps=6, p=3, m_workers=20, n=100,
                            alphas=(0.0, 0.1))
    _check_rows(rows, 2 * 2)
    for name, cov, width in rows:
        assert 0.0 <= cov <= 1.0, name
        assert width > 0, name
