"""repro.obs telemetry layer: fixed-edge histogram convention (jit
counts == host bisect), suspicion-score diagnostics ranking Byzantine
workers, serve-path disagreement drain (tokens bit-identical with
telemetry on), scheduler metrics, sinks round-trip, and the stdlib-only
import guarantee of the non-jax half."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get as get_arch
from repro.core import attacks as ATK
from repro.core.estimator import Estimator
from repro.models import model as Mo
from repro.obs import (Histogram, JsonlSink, MetricsRegistry, catalog,
                       merge_records, prometheus_text, read_jsonl)
from repro.obs.diag import (diagnose, histogram_counts, replica_disagreement,
                            tree_diagnose)
from repro.serve import (Request, RobustDecodeConfig, Scheduler, ServeEngine,
                         replica_mask, robust_logits)

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def dense():
    cfg = get_arch("qwen3-1.7b").reduced()
    params = Mo.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompt_batch(cfg, B, S, seed=1):
    return {"tokens": jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0,
                                         cfg.vocab)}


# ---------------------------------------------------------------------------
# Histogram mechanics (host side)
# ---------------------------------------------------------------------------

def test_histogram_record_and_percentiles():
    h = Histogram((1.0, 2.0, 5.0, 10.0))
    vals = [0.5, 1.5, 1.5, 3.0, 7.0, 20.0]
    h.record_many(vals)
    assert h.count == len(vals)
    assert h.min == 0.5 and h.max == 20.0
    assert abs(h.mean - np.mean(vals)) < 1e-12
    # percentiles are monotone and bracketed by the observed extremes
    ps = [h.percentile(q) for q in (1, 25, 50, 75, 99)]
    assert ps == sorted(ps)
    assert h.min <= ps[0] and ps[-1] <= h.max
    # the median sample (3.0) lives in bucket (2, 5]
    assert 2.0 <= h.percentile(50) <= 5.0


def test_histogram_edges_must_increase():
    with pytest.raises(ValueError):
        Histogram((1.0, 1.0, 2.0))
    with pytest.raises(ValueError):
        Histogram((2.0, 1.0))


def test_histogram_snapshot_merge_roundtrip():
    a = Histogram((1.0, 10.0))
    b = Histogram((1.0, 10.0))
    a.record_many([0.5, 5.0])
    b.record_many([20.0, 5.0])
    c = Histogram.from_snapshot(a.snapshot())
    c.merge(b)
    both = Histogram((1.0, 10.0))
    both.record_many([0.5, 5.0, 20.0, 5.0])
    assert c.snapshot() == both.snapshot()
    with pytest.raises(ValueError):
        a.merge(Histogram((1.0, 2.0)))


# ---------------------------------------------------------------------------
# Fixed-edge bucket convention: jit counts == host bisect
# ---------------------------------------------------------------------------

def test_histogram_counts_matches_host_convention():
    """``diag.histogram_counts`` (searchsorted left) and the host
    ``Histogram`` (bisect_left) must bucket identically — including
    values landing exactly on an edge — so jit counts drain losslessly."""
    edges = (0.0, 0.25, 0.5, 1.0)
    vals = [-1.0, 0.0, 0.1, 0.25, 0.3, 0.5, 0.75, 1.0, 2.0]
    dev = jax.jit(histogram_counts, static_argnums=1)(
        jnp.asarray(vals, jnp.float32), edges)
    host = Histogram(edges)
    host.record_many(vals)
    assert [int(c) for c in dev] == host.counts
    # merge_counts reproduces the host-recorded histogram exactly
    drained = Histogram(edges)
    drained.merge_counts([int(c) for c in dev], float(np.sum(vals)),
                         len(vals))
    assert drained.counts == host.counts
    assert drained.count == host.count
    assert abs(drained.sum - host.sum) < 1e-6


def test_merge_counts_length_mismatch_raises():
    h = Histogram((1.0, 2.0))
    with pytest.raises(ValueError):
        h.merge_counts([1, 2], 3.0, 2)  # needs len(edges) + 1 == 3


# ---------------------------------------------------------------------------
# Registry + catalog
# ---------------------------------------------------------------------------

def test_registry_counter_gauge_timer():
    reg = MetricsRegistry()
    reg.counter("serve.admitted")
    reg.counter("serve.admitted", 2)
    reg.gauge("serve.queue_depth", 5)
    with reg.timer("serve.ttft_s"):
        pass
    with reg.timer("serve.compile_s", kind="gauge"):
        pass
    assert reg.counters["serve.admitted"] == 3
    assert reg.gauges["serve.queue_depth"] == 5.0
    assert reg.histograms["serve.ttft_s"].count == 1
    assert reg.gauges["serve.compile_s"] >= 0.0
    # histogram edges come from the catalog entry for the name
    assert reg.histograms["serve.ttft_s"].edges == catalog.LATENCY_EDGES_S
    assert (reg.histogram("serve.replica_disagreement").edges
            == catalog.FRACTION_EDGES)


def test_catalog_registered_names():
    names = {m.name for m in catalog.METRICS}
    assert len(names) == len(catalog.METRICS)  # no duplicates
    for m in catalog.METRICS:
        assert m.kind in ("counter", "gauge", "histogram")
        assert (m.edges is not None) == (m.kind == "histogram")
    # every name the serve/train/launch paths record is registered
    for n in ("serve.ttft_s", "serve.decode_step_s", "serve.admitted",
              "serve.replica_disagreement", "agg.alpha_hat", "train.step_s",
              "launch.compile_flops"):
        assert n in names, n


def test_obs_stdlib_half_imports_without_jax():
    """catalog/metrics/sinks must work in a jax-less interpreter (docs
    CI): block jax imports and exercise the whole host-side path."""
    script = """
import sys

class _Block:
    def find_module(self, name, path=None):
        if name == "jax" or name.startswith("jax."):
            return self
    def load_module(self, name):
        raise ImportError(f"blocked: {name}")

sys.meta_path.insert(0, _Block())
import repro.obs as obs
reg = obs.MetricsRegistry()
reg.counter("serve.admitted")
reg.observe("serve.ttft_s", 0.01)
text = obs.prometheus_text(reg.snapshot())
assert "serve_admitted_total 1" in text
assert "serve_ttft_s_count 1" in text
assert "jax" not in sys.modules
print("NO-JAX-OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=120)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "NO-JAX-OK" in r.stdout


# ---------------------------------------------------------------------------
# Sinks: JSONL -> merge -> Prometheus
# ---------------------------------------------------------------------------

def test_sinks_jsonl_prometheus_roundtrip(tmp_path):
    path = str(tmp_path / "metrics.jsonl")
    reg = MetricsRegistry()
    reg.counter("serve.admitted", 2)
    reg.gauge("agg.alpha_hat", 0.25)
    reg.observe("serve.ttft_s", 0.05)
    with JsonlSink(path) as sink:
        sink.write_registry(reg, source="test", arch="x")
        sink.write_registry(reg)  # second record: counters/hists add up
    recs = read_jsonl(path)
    assert len(recs) == 2 and recs[0]["kind"] == "metrics"
    assert recs[0]["meta"] == {"source": "test", "arch": "x"}
    summary = merge_records(recs)
    assert summary["counters"]["serve.admitted"] == 4
    assert summary["gauges"]["agg.alpha_hat"] == 0.25
    assert summary["histograms"]["serve.ttft_s"]["count"] == 2
    text = prometheus_text(summary)
    # the TYPE line names the sample family (_total) — classic format
    assert "# TYPE serve_admitted_total counter" in text
    assert "serve_admitted_total 4" in text
    assert "agg_alpha_hat 0.25" in text
    assert 'serve_ttft_s_bucket{le="+Inf"} 2' in text
    assert "serve_ttft_s_count 2" in text


def test_metrics_dump_cli(tmp_path):
    path = str(tmp_path / "metrics.jsonl")
    reg = MetricsRegistry()
    reg.counter("serve.retired", 3)
    reg.histogram("serve.decode_step_s").record_many([0.01, 0.02, 0.04])
    with JsonlSink(path) as sink:
        sink.write_registry(reg)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(_REPO, "scripts", "metrics_dump.py"),
         path, "--format", "prometheus"],
        capture_output=True, text=True, env=env, timeout=120)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "serve_retired_total 3" in r.stdout
    assert "serve_decode_step_s_count 3" in r.stdout
    assert "serve_decode_step_s_p95" in r.stdout  # synthetic percentile
    # json format round-trips through the merge schema
    r2 = subprocess.run(
        [sys.executable, os.path.join(_REPO, "scripts", "metrics_dump.py"),
         path, "--format", "json", "--no-percentiles"],
        capture_output=True, text=True, env=env, timeout=120)
    assert r2.returncode == 0, r2.stderr
    summary = json.loads(r2.stdout)
    assert summary["counters"]["serve.retired"] == 3
    # missing file -> exit 2
    r3 = subprocess.run(
        [sys.executable, os.path.join(_REPO, "scripts", "metrics_dump.py"),
         str(tmp_path / "nope.jsonl")],
        capture_output=True, text=True, env=env, timeout=120)
    assert r3.returncode == 2


def test_metrics_dump_percentile_values_percent_scale(tmp_path):
    """The synthetic _p50/_p95/_p99 gauges take q in PERCENT: on a
    skewed distribution (90% fast, 10% slow) recorded through the dump
    path, p50 must land in the fast mass and p95/p99 in the slow tail —
    a fraction-scale call (0.95) would return ~the minimum."""
    path = str(tmp_path / "metrics.jsonl")
    reg = MetricsRegistry()
    vals = [0.001] * 90 + [0.5] * 10
    reg.histogram("serve.decode_step_s").record_many(vals)
    with JsonlSink(path) as sink:
        sink.write_registry(reg)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(_REPO, "scripts", "metrics_dump.py"),
         path, "--format", "json"],
        capture_output=True, text=True, env=env, timeout=120)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    g = json.loads(r.stdout)["gauges"]
    p50 = g["serve.decode_step_s_p50"]
    p95 = g["serve.decode_step_s_p95"]
    p99 = g["serve.decode_step_s_p99"]
    assert p50 <= p95 <= p99
    assert p50 < 0.01, p50    # median sits in the fast mass
    assert p95 >= 0.4, p95    # tail percentiles reach the slow samples
    assert p99 <= max(vals)


# ---------------------------------------------------------------------------
# Suspicion diagnostics: corrupted workers dominate the ranking
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("attack", ["signflip", "wrong_value"])
@pytest.mark.parametrize("alpha", [0.125, 0.25])
def test_suspicion_ranks_byzantine_workers(backend, attack, alpha):
    """floor(alpha*m) corrupted rows must take exactly the top suspicion
    scores, and the robust-z mask must flag exactly them."""
    m, d = 8, 64
    key = jax.random.PRNGKey(0)
    base = jax.random.normal(key, (d,))
    noise = 0.01 * jax.random.normal(jax.random.PRNGKey(1), (m, d))
    honest = base[None] + noise
    mask = replica_mask(m, alpha)
    n_byz = int(np.sum(np.asarray(mask)))
    assert n_byz == int(alpha * m) >= 1
    x = ATK.get(attack)(jax.random.PRNGKey(2), honest, mask)
    est = Estimator(method="vrmom", backend=backend)
    agg, diag = jax.jit(est.apply_with_diag)(x)
    # the aggregate is bit-identical to the diag-less apply
    np.testing.assert_array_equal(np.asarray(agg),
                                  np.asarray(jax.jit(est.apply)(x)))
    scores = np.asarray(diag.scores)
    top = set(np.argsort(scores)[-n_byz:])
    assert top == set(np.flatnonzero(np.asarray(mask))), scores
    np.testing.assert_array_equal(np.asarray(diag.suspected),
                                  np.asarray(mask))
    assert abs(float(diag.alpha_hat) - n_byz / m) < 1e-6
    assert diag.pre_norms.shape == (m,) and diag.post_norm.shape == ()


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_suspicion_all_false_when_honest(backend):
    """alpha = 0: a noisy all-honest stack must produce an all-false
    mask and alpha_hat == 0 (the relative floor absorbs the jitter)."""
    m, d = 8, 64
    base = jax.random.normal(jax.random.PRNGKey(0), (d,))
    x = base[None] + 0.01 * jax.random.normal(jax.random.PRNGKey(1), (m, d))
    est = Estimator(method="vrmom", backend=backend)
    _, diag = est.apply_with_diag(x)
    assert not np.asarray(diag.suspected).any()
    assert float(diag.alpha_hat) == 0.0


def test_suspicion_identical_rows_zero_scores():
    """The serve regime — deterministic replicas, identical rows — must
    give exact-zero scores, never float-jitter accusations."""
    x = jnp.broadcast_to(jax.random.normal(jax.random.PRNGKey(0), (32,)),
                         (6, 32))
    _, diag = Estimator(method="median", backend="jnp").apply_with_diag(x)
    assert np.asarray(diag.scores).max() == 0.0
    assert not np.asarray(diag.suspected).any()


def test_tree_diagnose_matches_flat():
    """Pytree diagnostics accumulate per-leaf second moments; the result
    must equal ``diagnose`` on the concatenated flat stack."""
    w = 6
    ka, kb = jax.random.split(jax.random.PRNGKey(3))
    tree = {"a": jax.random.normal(ka, (w, 4, 5)),
            "b": jax.random.normal(kb, (w, 7))}
    flat = jnp.concatenate([tree["a"].reshape(w, -1),
                            tree["b"].reshape(w, -1)], axis=1)
    agg_tree = jax.tree.map(lambda g: jnp.mean(g, axis=0), tree)
    agg_flat = jnp.mean(flat, axis=0)
    dt = tree_diagnose(tree, agg_tree)
    df = diagnose(flat, agg_flat)
    for a, b in zip(dt, df):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_with_diag_does_not_retrace():
    """apply_with_diag under jit: one trace serves every same-shape call
    (the diag aux is a pure function of the traced stack)."""
    est = Estimator(method="vrmom", backend="jnp")
    traces = []

    @jax.jit
    def f(x):
        traces.append(1)
        return est.apply_with_diag(x)

    x = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
    f(x)
    f(x + 1.0)
    assert len(traces) == 1


# ---------------------------------------------------------------------------
# Replica disagreement (serve wire signal)
# ---------------------------------------------------------------------------

def test_replica_disagreement_counts_argmax_mismatch():
    # m=4, B=2, V=3: replica 3 votes elsewhere for sequence 0 only
    agg = jnp.asarray([[9.0, 0.0, 0.0], [0.0, 9.0, 0.0]])
    logits_r = jnp.broadcast_to(agg[None], (4, 2, 3)).copy()
    logits_r = logits_r.at[3, 0].set(jnp.asarray([0.0, 0.0, 9.0]))
    rates = replica_disagreement(logits_r, agg)
    np.testing.assert_allclose(np.asarray(rates), [0.25, 0.0], atol=1e-7)


def test_robust_logits_with_diag_matches_alpha():
    """signflip at alpha=0.25, m=8 over identical honest logits: served
    logits unchanged vs the diag-less path, disagreement exactly 2/8."""
    m, B, V = 8, 3, 16
    rcfg = RobustDecodeConfig(m=m, estimator="median", attack="signflip",
                              alpha=0.25)
    honest = jax.random.normal(jax.random.PRNGKey(0), (B, V))
    stack = jnp.broadcast_to(honest[None], (m, B, V))
    key = jax.random.PRNGKey(1)
    agg0 = robust_logits(stack, rcfg, key)
    agg1, dis = robust_logits(stack, rcfg, key, with_diag=True)
    np.testing.assert_array_equal(np.asarray(agg0), np.asarray(agg1))
    # honest majority holds: served argmax == honest argmax
    np.testing.assert_array_equal(np.asarray(jnp.argmax(agg1, -1)),
                                  np.asarray(jnp.argmax(honest, -1)))
    np.testing.assert_allclose(np.asarray(dis), np.full((B,), 0.25),
                               atol=1e-7)


# ---------------------------------------------------------------------------
# Engine + scheduler integration
# ---------------------------------------------------------------------------

def test_engine_obs_tokens_bit_identical_and_drain(dense):
    """Telemetry on vs off: same compiled semantics (bit-identical
    tokens), and the disagreement histogram drains one counts vector per
    dispatch with exact count and the attack's disagreement rate."""
    cfg, params = dense
    rcfg = RobustDecodeConfig(m=4, estimator="median", attack="signflip",
                              alpha=0.25)
    batch = _prompt_batch(cfg, B=2, S=8)
    off = ServeEngine(cfg, params, max_len=32, robust=rcfg)
    reg = MetricsRegistry()
    on = ServeEngine(cfg, params, max_len=32, robust=rcfg, obs=reg)
    t_off = off.generate(batch, 6)
    t_on = on.generate(batch, 6)
    np.testing.assert_array_equal(np.asarray(t_off), np.asarray(t_on))
    h = reg.histograms["serve.replica_disagreement"]
    assert h.count == (6 - 1) * 2  # scanned tokens x batch
    # 1 of 4 replicas signflipped -> disagreement exactly 1/4 per token
    assert abs(h.mean - 0.25) < 1e-6
    # same shapes again: no new compiled programs, histogram accumulates
    n_fns = len(on._fns)
    on.generate(batch, 6)
    assert len(on._fns) == n_fns
    assert h.count == 2 * (6 - 1) * 2


def test_decode_pool_diag_masks_inactive_slots(dense):
    """Pool-path disagreement drain counts ACTIVE slots only: inactive
    slots decode stale/garbage caches and their rates must not dilute
    the per-request Byzantine signal (count = n_steps * n_active, and
    the mean stays exactly the attack's disagreement rate)."""
    cfg, params = dense
    rcfg = RobustDecodeConfig(m=4, estimator="median", attack="signflip",
                              alpha=0.25)
    reg = MetricsRegistry()
    eng = ServeEngine(cfg, params, max_len=32, n_slots=3, robust=rcfg,
                      obs=reg)
    pool = eng.make_pool()
    pool, first = eng.admit(pool, 0, _prompt_batch(cfg, B=1, S=8))
    n_steps = 4
    pool, _ = eng.decode_pool(pool, np.asarray([first, 0, 0], np.int32),
                              n_steps)
    h = reg.histograms["serve.replica_disagreement"]
    assert h.count == n_steps * 1, h.count  # 1 active of 3 slots
    # 1 of 4 replicas signflipped -> disagreement exactly 1/4 per token
    assert abs(h.mean - 0.25) < 1e-6, h.mean


def test_engine_without_robust_records_nothing(dense):
    """obs without a robust config: the plain decode loop carries no
    diag aux (nothing to disagree about) and stays 2-output."""
    cfg, params = dense
    reg = MetricsRegistry()
    eng = ServeEngine(cfg, params, max_len=32, obs=reg)
    eng.generate(_prompt_batch(cfg, B=2, S=8), 6)
    assert "serve.replica_disagreement" not in reg.histograms


def test_scheduler_records_serve_metrics(dense):
    cfg, params = dense
    reg = MetricsRegistry()
    eng = ServeEngine(cfg, params, max_len=48, n_slots=2, obs=reg)
    sched = Scheduler(eng, decode_block=3)
    rs = np.random.RandomState(0)
    uids = [sched.submit(Request(tokens=rs.randint(0, cfg.vocab, size=(6,)),
                                 max_new_tokens=4)) for _ in range(3)]
    # cannot fit: prompt + budget + block overshoot > max_len
    big = sched.submit(Request(tokens=rs.randint(0, cfg.vocab, size=(40,)),
                               max_new_tokens=16))
    done = sched.run()
    assert sorted(done) == sorted(uids + [big])
    assert done[big].finished_by == "rejected"
    c = reg.counters
    assert c["serve.admitted"] == 3
    assert c["serve.retired"] == 3
    assert c["serve.rejected"] == 1
    assert c["serve.tokens_out"] == sum(len(done[u].tokens) for u in uids)
    # first admission at the (6,) prompt shape compiles the prefill
    # program, so it lands in serve.compile_s, not the TTFT histogram
    assert reg.histograms["serve.ttft_s"].count == 2
    assert reg.gauges["serve.compile_s"] > 0.0
    assert reg.histograms["serve.decode_step_s"].count >= 1
    assert reg.gauges["serve.queue_depth"] == 0.0  # last cycle: drained
    assert "serve.slots_active" in reg.gauges


# ---------------------------------------------------------------------------
# Train-path diagnostics (8 host devices, subprocess)
# ---------------------------------------------------------------------------

def _run(script, devices=8, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_train_step_with_diag_flags_byzantine_worker():
    """Sharded train step with with_diag=True: the wrong_value worker
    must top the suspicion ranking; diagnostics ride the jitted step as
    static-shape aux, and the loss matches the diag-less step exactly.
    inloop mode has no stacked gradient to diagnose and must refuse."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get as get_arch
from repro.data import lm_batch, shard_batch
from repro.models import model as M
from repro.train.step import make_train_step
import repro.optim as O
from repro.dist import sharding as S

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_arch("qwen3-1.7b").reduced()
params = M.init(jax.random.PRNGKey(0), cfg)

def run(with_diag):
    setup = make_train_step(cfg, mesh, estimator="vrmom", mode="stacked-rrs",
                            byzantine_frac=0.4, attack="wrong_value",
                            lr=1e-2, with_diag=with_diag)
    opt = O.get(cfg.optimizer, lr=1e-2)
    p = jax.device_put(params, S.to_named(mesh, setup.params_specs))
    st = jax.jit(opt.init)(p)
    step = jax.jit(setup.step_fn)
    diag = None
    for i in range(2):
        b = shard_batch(lm_batch(cfg, i, 8, 32), mesh, setup.batch_axes)
        if with_diag:
            p, st, loss, diag = step(p, st, b, jax.random.PRNGKey(i))
        else:
            p, st, loss = step(p, st, b, jax.random.PRNGKey(i))
    return float(loss), diag

loss_plain, _ = run(False)
loss_diag, diag = run(True)
assert loss_plain == loss_diag, (loss_plain, loss_diag)
scores = np.asarray(diag.scores)
assert scores.shape == (4,)
# 0.4 of 3 non-master workers -> 1 Byzantine (the last worker), whose
# wrong_value gradient dominates the deviation ranking
assert int(np.argmax(scores)) == 3, scores
assert bool(np.asarray(diag.suspected)[3])
assert not np.asarray(diag.suspected)[:3].any()
assert abs(float(diag.alpha_hat) - 0.25) < 1e-6
assert np.isfinite(np.asarray(diag.pre_norms)).all()
assert np.isfinite(float(diag.post_norm))

try:
    make_train_step(cfg, mesh, mode="inloop", with_diag=True)
except ValueError as e:
    assert "inloop" in str(e)
else:
    raise AssertionError("inloop + with_diag must refuse")
print("OBS-TRAIN-OK", loss_diag)
""", timeout=1800)
    assert "OBS-TRAIN-OK" in out


def test_rrs_aggregate_with_diag_matches_plain():
    """aggregate(..., with_diag=True) over the RRS wire: the aggregate
    matches the diag-less call bit-for-bit and the diagnostics flag the
    corrupted row of a signflip-attacked stacked pytree."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.dist import robust_reduce as RR
mesh = jax.make_mesh((4, 2), ("data", "model"))
g = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 6, 16)) + 2.0}
g["w"] = g["w"].at[3].multiply(-1.0)  # worker 3 signflips on the wire
sh = {"w": NamedSharding(mesh, P("data", None, "model"))}
gp = jax.tree.map(jax.device_put, g, sh)
plain = jax.jit(lambda x: RR.aggregate(x, mesh, ("data",)))(gp)
agg, diag = jax.jit(
    lambda x: RR.aggregate(x, mesh, ("data",), with_diag=True))(gp)
np.testing.assert_array_equal(np.asarray(plain["w"]), np.asarray(agg["w"]))
scores = np.asarray(diag.scores)
assert int(np.argmax(scores)) == 3, scores
assert bool(np.asarray(diag.suspected)[3])
print("RRS-DIAG-OK")
""")
    assert "RRS-DIAG-OK" in out
