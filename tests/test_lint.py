"""reprolint self-tests: one true-positive and one true-negative per
rule ID, waiver mechanics (RL000), the construction-time hashability
backstops, and the auditor's flagged-config paths."""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.lint import (Report, UnhashableFieldError, check_hashable_fields,
                        lint_source, rule_ids)
from repro.lint.catalog import ALL_IDS, AST_RULES, AUDIT_CHECKS

REPO = Path(__file__).resolve().parents[1]


def ids(findings, *, include_waived=False):
    return sorted(f.rule_id for f in findings
                  if include_waived or not f.waived)


def run(src, relpath="src/repro/train/somefile.py"):
    return lint_source(textwrap.dedent(src), relpath)


# ---------------------------------------------------------------------------
# catalog sanity
# ---------------------------------------------------------------------------

def test_catalog_covers_registered_rules():
    assert set(rule_ids()) <= set(r.id for r in AST_RULES)
    assert len(set(ALL_IDS)) == len(ALL_IDS)
    assert all(r.invariant and r.established
               for r in AST_RULES + AUDIT_CHECKS)


# ---------------------------------------------------------------------------
# RL000 — waiver mechanics
# ---------------------------------------------------------------------------

def test_rl000_waiver_without_reason_is_a_finding():
    fs = run("""
        import jax.numpy as jnp
        def f(x):
            # reprolint: disable=RL001
            return jnp.median(x, axis=0)
        """)
    assert "RL000" in ids(fs)
    assert "RL001" in ids(fs)  # unexcused -> still active


def test_rl000_reasoned_waiver_suppresses():
    fs = run("""
        import jax.numpy as jnp
        def f(x):
            # reprolint: disable=RL001 reference oracle for the dispatch test
            return jnp.median(x, axis=0)
        """)
    assert ids(fs) == []
    assert ids(fs, include_waived=True) == ["RL001"]


def test_rl000_stale_waiver_is_a_finding():
    fs = run("""
        # reprolint: disable=RL002 there is nothing repeated here
        x = 1
        """)
    assert ids(fs) == ["RL000"]


def test_rl000_docstring_mention_is_not_a_waiver():
    fs = run('''
        def f():
            """Docs may say `# reprolint: disable=RL001` without waiving."""
            return 0
        ''')
    assert ids(fs) == []


# ---------------------------------------------------------------------------
# RL001 — direct-aggregation-bypass
# ---------------------------------------------------------------------------

def test_rl001_true_positive_median_and_import():
    fs = run("""
        import jax.numpy as jnp
        from repro.core import aggregators
        def f(x):
            return jnp.median(x, axis=0) + aggregators.trimmed_mean(x, 0.1)
        """)
    assert ids(fs).count("RL001") == 3


def test_rl001_true_negative_estimator_layer_and_numpy():
    # inside the allowlisted estimator layer the same code is legal
    fs = lint_source(textwrap.dedent("""
        import jax.numpy as jnp
        def f(x):
            return jnp.median(x, axis=0)
        """), "src/repro/core/estimator.py")
    assert ids(fs) == []
    # host-side numpy oracles are not on the jit path
    fs = run("""
        import numpy as np
        def f(x):
            return np.median(x, axis=0)
        """)
    assert ids(fs) == []


# ---------------------------------------------------------------------------
# RL002 — kv-head-repeat
# ---------------------------------------------------------------------------

def test_rl002_true_positive_kv_repeat_in_models():
    fs = lint_source(textwrap.dedent("""
        import jax.numpy as jnp
        def mha(q, k, v):
            k = jnp.repeat(k, 4, axis=2)
            v = jnp.repeat(v, 4, axis=2)
            return q
        """), "src/repro/models/myattn.py")
    assert ids(fs) == ["RL002", "RL002"]


def test_rl002_true_negative_ssm_state_and_other_dirs():
    # mamba-style state expansion: not a K/V name
    fs = lint_source(textwrap.dedent("""
        import jax.numpy as jnp
        def ssm(B, C, nh):
            B = jnp.repeat(B, nh, axis=1)
            return B
        """), "src/repro/models/mamba2.py")
    assert ids(fs) == []
    # same call outside models//kernels/ is out of scope
    fs = lint_source(textwrap.dedent("""
        import jax.numpy as jnp
        def f(k):
            return jnp.repeat(k, 4, axis=2)
        """), "src/repro/data/loader.py")
    assert ids(fs) == []


# ---------------------------------------------------------------------------
# RL003 — trace-unsafe-python
# ---------------------------------------------------------------------------

def test_rl003_true_positive_branch_and_cast():
    fs = run("""
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return int(x)
        """)
    assert ids(fs) == ["RL003", "RL003"]


def test_rl003_jit_callsite_with_static_argnames():
    fs = run("""
        import jax

        def f(x, mode):
            if mode == "fast":   # static -> fine
                return x
            if x.shape[0] > 2:   # shape read -> fine
                return x + 1
            if x > 0:            # traced -> flagged
                return x - 1
            return x

        g = jax.jit(f, static_argnames=("mode",))
        """)
    assert ids(fs) == ["RL003"]


def test_rl003_true_negative_shape_none_and_unjitted():
    fs = run("""
        import jax

        @jax.jit
        def f(x, y):
            if y is None:
                return x
            if len(x.shape) > 2:
                return x + 1
            return x

        def g(x):
            if x > 0:   # not jitted -> out of scope
                return 1
            return int(x)
        """)
    assert ids(fs) == []


# ---------------------------------------------------------------------------
# RL004 — unhashable-static
# ---------------------------------------------------------------------------

def test_rl004_true_positive_unfrozen_and_mutable_field():
    fs = run("""
        import dataclasses
        from typing import List, NamedTuple

        @dataclasses.dataclass
        class DecodeConfig:
            m: int = 8

        class TileSpec(NamedTuple):
            dims: List[int]
        """)
    assert ids(fs) == ["RL004", "RL004"]


def test_rl004_true_negative_frozen_config_and_host_record():
    fs = run("""
        import dataclasses

        @dataclasses.dataclass(frozen=True)
        class DecodeConfig:
            m: int = 8
            name: str = "x"

        @dataclasses.dataclass
        class Request:        # host-side bookkeeping: not config-named
            prompt: str = ""
        """)
    assert ids(fs) == []


# ---------------------------------------------------------------------------
# RL005 — impure-index-map
# ---------------------------------------------------------------------------

def test_rl005_true_positive_subscript_and_call():
    fs = run("""
        from jax.experimental import pallas as pl
        def f(table):
            return pl.BlockSpec((1, 8), lambda i, j: (table[i], j))
        def g(fn):
            return pl.BlockSpec((1, 8), index_map=lambda i, j: (fn(i), j))
        """)
    assert ids(fs) == ["RL005", "RL005"]


def test_rl005_true_negative_pure_arithmetic():
    fs = run("""
        from jax.experimental import pallas as pl
        H, G = 8, 2
        def f():
            return pl.BlockSpec(
                (1, 8), lambda b, i, j: ((b // H) * G + (b % H) // G, j, 0))
        """)
    assert ids(fs) == []


# ---------------------------------------------------------------------------
# RL006 — unmasked-padded-load
# ---------------------------------------------------------------------------

def test_rl006_true_positive_padded_without_mask():
    fs = run("""
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _kern(x_ref, o_ref):
            o_ref[...] = x_ref[...] * 2.0

        def f(x, blk):
            x = jnp.pad(x, ((0, 3), (0, 0)))
            return pl.pallas_call(_kern, grid=(4,),
                                  out_shape=x)(x)
        """)
    assert ids(fs) == ["RL006"]


def test_rl006_true_negative_masked_or_unpadded():
    fs = run("""
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _kern(x_ref, o_ref, *, n):
            i = jax.lax.broadcasted_iota(jnp.int32, x_ref.shape, 0)
            o_ref[...] = jnp.where(i < n, x_ref[...], 0.0)

        def masked(x, n):
            x = jnp.pad(x, ((0, 3), (0, 0)))
            import functools
            return pl.pallas_call(functools.partial(_kern, n=n),
                                  grid=(4,), out_shape=x)(x)

        def _kern2(x_ref, o_ref):
            o_ref[...] = x_ref[...] * 2.0

        def unpadded(x):
            return pl.pallas_call(_kern2, grid=(4,), out_shape=x)(x)
        """)
    assert ids(fs) == []


def test_rl006_true_positive_partial_bound_kernel():
    """The fused-tail shape: a wrapper that pads rows, then dispatches a
    functools.partial-bound kernel with NO mask anywhere — must flag."""
    fs = run("""
        import functools
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _tail(x_ref, o_ref, *, m):
            o_ref[...] = jnp.sum(x_ref[...], axis=0)

        def fused(x, m_pad):
            x = jnp.pad(x, ((0, m_pad - x.shape[0]), (0, 0)))
            return pl.pallas_call(functools.partial(_tail, m=x.shape[0]),
                                  grid=(4,), out_shape=x)(x)
        """)
    assert ids(fs) == ["RL006"]


def test_rl006_true_negative_mask_in_module_helper():
    """The mask may live in a same-module helper the kernel calls (the
    vrmom kernels share ``_agg_block``) — the rule follows plain-name
    calls to module-level defs before flagging."""
    fs = run("""
        import functools
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _shared_block(x, n):
            i = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
            return jnp.where(i < n, x, 0.0)

        def _kern(x_ref, o_ref, *, n):
            o_ref[...] = _shared_block(x_ref[...], n)

        def padded(x, n):
            x = jnp.pad(x, ((0, 3), (0, 0)))
            return pl.pallas_call(functools.partial(_kern, n=n),
                                  grid=(4,), out_shape=x)(x)
        """)
    assert ids(fs) == []


# ---------------------------------------------------------------------------
# RL007 — wall-clock-outside-obs
# ---------------------------------------------------------------------------

def test_rl007_true_positive_clock_call_and_import():
    fs = run("""
        import time
        from time import perf_counter

        def f():
            t0 = time.time()
            t1 = perf_counter()
            return time.monotonic() - t0 + t1
        """)
    # the bare perf_counter() call is caught at its import site
    assert ids(fs) == ["RL007", "RL007", "RL007"]


def test_rl007_true_negative_obs_layer_and_nonclock_time():
    # the obs layer IS the allowed wall-clock site
    fs = lint_source(textwrap.dedent("""
        import time

        def now():
            return time.perf_counter()
        """), "src/repro/obs/metrics.py")
    assert ids(fs) == []
    # non-clock time functions (sleep, strftime) are fine anywhere
    fs = run("""
        import time

        def f():
            time.sleep(0.1)
            return time.strftime("%Y")
        """)
    assert ids(fs) == []


def test_rl007_scope_is_library_code_only():
    src = """
        import time

        def f():
            return time.time()
        """
    for relpath in ("benchmarks/serve.py", "examples/serve.py",
                    "scripts/metrics_dump.py", "tests/test_obs.py"):
        assert ids(lint_source(textwrap.dedent(src), relpath)) == []
    assert ids(lint_source(textwrap.dedent(src),
                           "src/repro/serve/scheduler.py")) == ["RL007"]


def test_rl007_repo_library_tree_is_clean():
    """The invariant holds on the actual tree: no direct wall-clock
    reads anywhere under src/repro/ outside obs/metrics.py."""
    from repro.lint import lint_paths

    findings = [f for f in lint_paths([str(REPO / "src" / "repro")],
                                      root=str(REPO))
                if f.rule_id == "RL007" and not f.waived]
    assert findings == [], findings


# ---------------------------------------------------------------------------
# hashability backstops (satellite 2)
# ---------------------------------------------------------------------------

def test_estimator_rejects_unhashable_field():
    from repro.core.estimator import Estimator

    with pytest.raises(UnhashableFieldError, match=r"Estimator\.K"):
        Estimator(method="median", K=[1, 2])
    hash(Estimator(method="median"))  # clean spec stays hashable


def test_robust_decode_config_rejects_unhashable_field():
    from repro.serve.robust import RobustDecodeConfig

    with pytest.raises(UnhashableFieldError, match=r"\.attack"):
        RobustDecodeConfig(m=8, estimator="median", attack=["none"])
    hash(RobustDecodeConfig(m=8, estimator="median"))


def test_arch_config_rejects_unhashable_field():
    from repro.configs.base import ArchConfig

    with pytest.raises(UnhashableFieldError, match=r"ArchConfig\.source"):
        ArchConfig(name="x", family="dense", n_layers=1, d_model=8,
                   n_heads=2, n_kv_heads=1, d_ff=16, vocab=32,
                   source=["paper"])


def test_check_hashable_fields_plain_object():
    class Box:
        def __init__(self):
            self.data = {"a": 1}

    with pytest.raises(UnhashableFieldError, match=r"Box\.data"):
        check_hashable_fields(Box())


# ---------------------------------------------------------------------------
# auditor: flagged configs (satellite 3)
# ---------------------------------------------------------------------------

def test_auditor_flags_worker_indivisible_config():
    from repro.lint.auditor import divisibility_audit

    bad = divisibility_audit("train.global_batch", batch=9, n_workers=8)
    assert bad.status == "fail"
    assert "not divisible" in bad.detail
    good = divisibility_audit("train.global_batch", batch=16, n_workers=8)
    assert good.status == "ok"


def test_auditor_flags_hash_unstable_config():
    import dataclasses

    from repro.lint.auditor import recompile_stability

    @dataclasses.dataclass(frozen=True, eq=False)  # hash by identity
    class DriftyConfig:
        m: int = 8

    bad = recompile_stability("DriftyConfig", DriftyConfig)
    assert bad.status == "fail"

    from repro.core.estimator import Estimator

    good = recompile_stability("Estimator",
                               lambda: Estimator(method="median"))
    assert good.status == "ok", good.detail


def test_auditor_flags_consensus_validity_region():
    from repro.lint.auditor import consensus_validity_audit

    bad = consensus_validity_audit("dist.consensus", n=8, f=2)
    assert bad.status == "fail"
    assert "n > 5f" in bad.detail
    boundary = consensus_validity_audit("dist.consensus", n=10, f=2)
    assert boundary.status == "fail"  # n == 5f is still invalid
    good = consensus_validity_audit("dist.consensus", n=8, f=1)
    assert good.status == "ok", good.detail
    assert good.check_id == "RL210"


def test_auditor_full_run_has_no_failures():
    """The shipped tree passes its own audit (skips allowed off-mesh)."""
    from repro.lint.auditor import run_audit

    results = run_audit()
    fails = [r for r in results if r.status == "fail"]
    assert not fails, "\n".join(r.render() for r in fails)
    # every advertised RL2xx check reported at least once
    seen = {r.check_id for r in results}
    assert {c.id for c in AUDIT_CHECKS} <= seen | {"RL201", "RL205",
                                                   "RL206"}


# ---------------------------------------------------------------------------
# CLI + shipped tree (acceptance)
# ---------------------------------------------------------------------------

def test_shipped_tree_is_lint_clean():
    from repro.lint import lint_paths

    findings = lint_paths(["src", "tests"], str(REPO))
    report = Report(findings=findings, audit=[])
    assert report.errors == [], report.render_text()
    # zero unexplained suppressions
    assert all(f.waive_reason for f in findings if f.waived)


def test_cli_exits_nonzero_on_violation(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("import jax.numpy as jnp\n"
                   "def f(x):\n"
                   "    return jnp.median(x, axis=0)\n")
    proc = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "reprolint.py"),
         str(bad), "--format", "json"],
        capture_output=True, text=True)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert '"RL001"' in proc.stdout
    # warn-only downgrades to exit 0 but still reports
    proc = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "reprolint.py"),
         str(bad), "--warn-only"],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "1 warning" in proc.stdout
