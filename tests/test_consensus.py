"""Tests for the decentralized consensus backend (DESIGN.md §13).

Single-device tests exercise the emulation path (``consensus_iterate``
/ ``consensus_aggregate`` on a host [n, C] stack); the shard_map wire
and the consensus train step run in an 8-device SUBPROCESS via the same
``_run`` harness as tests/test_distributed.py.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks as A
from repro.dist import robust_reduce as RR
from repro.dist.consensus import (ConsensusConfig, consensus_aggregate,
                                  consensus_iterate)
from repro.dist.faults import FaultPlan

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


def _stack(n=8, C=37, key=0):
    return jax.random.normal(jax.random.PRNGKey(key), (n, C))


# ---------------------------------------------------------------- emulation

@pytest.mark.parametrize("est", ["vrmom", "median", "mean"])
def test_fault_free_matches_direct_aggregation(est):
    """No faults, trim='mean', no pin: the consensus value is EXACTLY
    the direct robust aggregate (round 1 is idempotent)."""
    v = _stack()
    cfg = ConsensusConfig(f=1).validate(v.shape[0])
    got, aux = consensus_aggregate(v, est, config=cfg)
    want = RR.aggregate_stacked_auto({"g": v}, est)["g"]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert not bool(aux.quorum_lost)
    assert float(aux.spread) <= cfg.eps


def test_refuses_n_le_5f():
    v = _stack(n=8)
    with pytest.raises(ValueError, match="n > 5f"):
        consensus_aggregate(v, "vrmom", config=ConsensusConfig(f=2))
    # boundary: n = 5f exactly is still invalid
    with pytest.raises(ValueError, match="n > 5f"):
        ConsensusConfig(f=1).validate(5)
    ConsensusConfig(f=1).validate(6)  # minimal valid population


def test_convergence_under_dropout_and_byzantine_pin():
    """10% message loss + a persistent Byzantine sender: honest values
    still contract to eps, and the aux telemetry is coherent."""
    n = 8
    v = _stack(n=n)
    mask = jnp.arange(n) >= n - 1              # last row Byzantine
    assert int(mask.sum()) == 1
    v_att = A.omniscient(jax.random.PRNGKey(3), v, mask)
    cfg = ConsensusConfig(f=1, trim="midpoint").validate(n)
    plan = FaultPlan(dropout=0.1).validate(n)
    finals, aux = consensus_iterate(v_att, "vrmom", config=cfg, plan=plan,
                                    key=jax.random.PRNGKey(9), pin_mask=mask)
    assert np.isfinite(np.asarray(finals)).all()
    assert float(aux.spread) <= cfg.eps
    assert int(aux.rounds_to_eps) <= int(aux.rounds_run)
    assert int(aux.messages_dropped) > 0
    assert 0.0 < float(aux.quorum) <= 1.0
    assert not bool(aux.quorum_lost)
    # honest finals agree with each other and stay near the honest cloud
    honest = np.asarray(finals)[: n - 1]
    assert np.abs(honest - honest[0]).max() <= cfg.eps
    ref = np.asarray(v)[: n - 1].mean(0)
    assert np.abs(honest[0] - ref).max() < 3.0


def test_crash_within_quorum_converges():
    n, v = 8, _stack()
    cfg = ConsensusConfig(f=1).validate(n)
    plan = FaultPlan(n_crashed=1, crash_round=1).validate(n)
    got, aux = consensus_aggregate(v, "vrmom", config=cfg, plan=plan,
                                   key=jax.random.PRNGKey(1))
    assert np.isfinite(np.asarray(got)).all()
    assert not bool(aux.quorum_lost)
    assert float(aux.spread) <= cfg.eps


def test_quorum_loss_flags_not_nan():
    """Crashes beyond n - f: the backend degrades gracefully — finite
    output, quorum gauge collapses, quorum_lost flag raised. Never NaN."""
    n, v = 8, _stack()
    cfg = ConsensusConfig(f=1).validate(n)
    plan = FaultPlan(n_crashed=3, crash_round=0).validate(n)
    got, aux = consensus_aggregate(v, "vrmom", config=cfg, plan=plan,
                                   key=jax.random.PRNGKey(2))
    assert np.isfinite(np.asarray(got)).all(), "quorum loss must not NaN"
    assert bool(aux.quorum_lost)
    assert float(aux.quorum) < 0.5
    assert np.isfinite(float(aux.spread))


def test_stragglers_converge():
    n, v = 8, _stack()
    cfg = ConsensusConfig(f=1).validate(n)
    plan = FaultPlan(n_stragglers=2, stale_rounds=2).validate(n)
    _, aux = consensus_aggregate(v, "vrmom", config=cfg, plan=plan,
                                 key=jax.random.PRNGKey(4))
    assert float(aux.spread) <= cfg.eps
    assert not bool(aux.quorum_lost)


@pytest.mark.parametrize("attack", ["ipm", "mimic"])
def test_omniscient_pin_composition_stays_bounded(attack):
    """S4 (DESIGN.md §14): omniscient attack payloads re-broadcast every
    round by the *pinned* Byzantine rows compose with the consensus
    trim: the honest consensus value stays inside the honest cloud and
    quorum holds — for both a loud payload (ipm at eps=100) and a
    legitimate-looking one (mimic)."""
    n = 16
    v = _stack(n=n, key=5)
    mask = jnp.arange(n) >= n - 3            # 3 pinned Byzantine, 16 > 5*3
    if attack == "ipm":
        v_att = A.ipm(jax.random.PRNGKey(8), v, mask, eps=100.0)
    else:
        v_att = A.mimic(jax.random.PRNGKey(8), v, mask)
    cfg = ConsensusConfig(f=3).validate(n)
    got, aux = consensus_aggregate(v_att, "vrmom", config=cfg,
                                   key=jax.random.PRNGKey(12), pin_mask=mask)
    assert np.isfinite(np.asarray(got)).all()
    assert not bool(aux.quorum_lost)
    assert float(aux.spread) <= cfg.eps
    ref = np.asarray(v)[: n - 3].mean(0)     # honest reference
    assert np.abs(np.asarray(got) - ref).max() < 3.0


def test_omniscient_pin_mean_control_diverges():
    """The contrast cell for S4: the same pinned ipm payload through an
    untrimmed mean consensus (f=0) drags the value far from the honest
    cloud — robust trimming, not the consensus rounds, is what bounds
    the error above."""
    n = 16
    v = _stack(n=n, key=5)
    mask = jnp.arange(n) >= n - 3
    v_att = A.ipm(jax.random.PRNGKey(8), v, mask, eps=100.0)
    ref = np.asarray(v)[: n - 3].mean(0)
    robust, _ = consensus_aggregate(
        v_att, "vrmom", config=ConsensusConfig(f=3).validate(n),
        key=jax.random.PRNGKey(12), pin_mask=mask)
    control, _ = consensus_aggregate(
        v_att, "mean", config=ConsensusConfig(f=0).validate(n),
        key=jax.random.PRNGKey(12), pin_mask=mask)
    err_r = np.linalg.norm(np.asarray(robust) - ref)
    err_c = np.linalg.norm(np.asarray(control) - ref)
    assert err_c > 5.0 * err_r + 1.0, (err_c, err_r)


def test_aux_fields_are_scalars():
    v = _stack()
    _, aux = consensus_aggregate(v, "vrmom",
                                 config=ConsensusConfig(f=1).validate(8))
    for name, val in aux._asdict().items():
        assert jnp.shape(val) == (), (name, jnp.shape(val))


def test_auto_consensus_backend_roundtrip():
    """aggregate_stacked_auto(reduce_backend='consensus') flattens a
    pytree onto one wire and returns leaves with original shape/dtype,
    matching the direct backend fault-free."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 4, 6)),
         "b": jax.random.normal(jax.random.PRNGKey(1), (8, 5))
              .astype(jnp.bfloat16)}
    cfg = ConsensusConfig(f=1).validate(8)
    out, aux = RR.aggregate_stacked_auto(g, "vrmom",
                                         reduce_backend="consensus",
                                         consensus=cfg)
    direct = RR.aggregate_stacked_auto(g, "vrmom")
    for k in g:
        assert out[k].shape == g[k].shape[1:]
        assert out[k].dtype == g[k].dtype
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(direct["w"]), rtol=1e-6, atol=1e-6)
    assert not bool(aux.quorum_lost)


# ------------------------------------------------------- 8-device subprocess

def test_shard_map_consensus_matches_rrs_and_emulation():
    """On a real 8-device mesh: fault-free consensus == RRS exactly,
    and the faulty shard_map wire is bit-identical to the emulation
    (same key -> same recv matrices -> same trajectory)."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.dist import robust_reduce as RR
from repro.dist.consensus import (ConsensusConfig, aggregate_stacked_consensus,
                                  consensus_aggregate)
from repro.dist.faults import FaultPlan
mesh = jax.make_mesh((8, 1), ("data", "model"))
g = {"w": jax.random.normal(jax.random.PRNGKey(2), (8, 12, 8)),
     "b": jax.random.normal(jax.random.PRNGKey(3), (8, 7))}
sh = {"w": NamedSharding(mesh, P("data", None, "model")),
      "b": NamedSharding(mesh, P("data", None))}
gp = jax.tree.map(jax.device_put, g, sh)
cfg = ConsensusConfig(f=1).validate(8)

out, aux = jax.jit(lambda x: aggregate_stacked_consensus(
    x, mesh, ("data",), "vrmom", config=cfg))(gp)
rrs = jax.jit(lambda x: RR.aggregate_stacked_rrs(
    x, mesh, ("data",), "vrmom"))(gp)
for k in g:
    np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(rrs[k]))
assert not bool(aux.quorum_lost)
print("CONS-EQ-RRS")

# faulty wire vs emulation, bit for bit (values and aux)
plan = FaultPlan(dropout=0.2, n_crashed=1, crash_round=1).validate(8)
key = jax.random.PRNGKey(11)
out_f, aux_f = jax.jit(lambda x: aggregate_stacked_consensus(
    x, mesh, ("data",), "vrmom", config=cfg, plan=plan, key=key))(gp)
wire = jnp.concatenate([g["w"].reshape(8, -1), g["b"].reshape(8, -1)], axis=1)
want, aux_e = consensus_aggregate(wire, "vrmom", config=cfg, plan=plan,
                                  key=key)
got = jnp.concatenate([out_f["w"].reshape(-1), out_f["b"].reshape(-1)])
np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
for name in aux_e._fields:
    np.testing.assert_array_equal(np.asarray(getattr(aux_f, name)),
                                  np.asarray(getattr(aux_e, name)), err_msg=name)
print("CONS-EQ-EMU")
""")
    assert "CONS-EQ-RRS" in out and "CONS-EQ-EMU" in out


def test_train_step_consensus_under_attack_and_dropout():
    """End-to-end sharded training with the consensus backend: ALIE
    attacker + 10% dropout + a mid-run crash stays finite and learns."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get as get_arch
from repro.data import lm_batch, shard_batch
from repro.models import model as M
from repro.train.step import make_train_step
import repro.optim as O
from repro.dist import sharding as S
from repro.dist.consensus import ConsensusConfig
from repro.dist.faults import FaultPlan

mesh = jax.make_mesh((8, 1), ("data", "model"))
cfg = get_arch("qwen3-1.7b").reduced()
plan = FaultPlan(dropout=0.1, n_crashed=1, crash_round=2)
setup = make_train_step(cfg, mesh, estimator="vrmom",
                        reduce_backend="consensus",
                        consensus=ConsensusConfig(f=1),
                        fault_plan=plan,
                        byzantine_frac=0.15, attack="alie", lr=1e-2)
# 0.15 * 7 floors to exactly one Byzantine worker; 0.125 would floor to
# zero and silently test nothing.
assert int(0.15 * (8 - 1)) == 1
assert setup.n_workers == 8
opt = O.get(cfg.optimizer, lr=1e-2)
params = M.init(jax.random.PRNGKey(0), cfg)
p = jax.device_put(params, S.to_named(mesh, setup.params_specs))
st = jax.jit(opt.init)(p)
step = jax.jit(setup.step_fn)
losses = []
for i in range(6):
    b = shard_batch(lm_batch(cfg, i, 8, 32), mesh, setup.batch_axes)
    p, st, loss, caux = step(p, st, b, jax.random.PRNGKey(i))
    losses.append(float(loss))
    assert np.isfinite(losses[-1])
    assert not bool(caux.quorum_lost)
    assert int(caux.rounds_run) >= 1
assert losses[-1] < losses[0], losses
print("CONS-TRAIN-OK", losses[0], losses[-1])
""", timeout=1800)
    assert "CONS-TRAIN-OK" in out


def test_coverage_cell_under_consensus():
    """Statistical cell (rcsl + sandwich CI) through the consensus wire
    with dropout: coverage stays near nominal."""
    out = _run("""
import numpy as np
from repro.infer.coverage import coverage_run
from repro.dist.consensus import ConsensusConfig
from repro.dist.faults import FaultPlan
cell = coverage_run(model="linear", attack="alie", alpha=0.1,
                    estimator="vrmom", K=5, reps=16, N_per_machine=100,
                    m_workers=20, p=3, rounds=4, batch_size=8,
                    reduce_backend="consensus",
                    consensus=ConsensusConfig(f=2),
                    fault_plan=FaultPlan(dropout=0.1))
s = cell.summary()
assert np.isfinite(s["rmse"])
assert s["coverage"] >= 0.6, s
print("CONS-COVERAGE-OK", s["coverage"])
""", devices=1, timeout=1200)
    assert "CONS-COVERAGE-OK" in out
