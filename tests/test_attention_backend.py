"""Backend-parity suite for the attention layer (DESIGN.md §8).

The decode kernel must equal the chunked jnp ``mha`` reference to 1e-5
across GQA ratios, scalar vs per-row ``kv_len``, ring vs linear cache
geometry, odd head counts, and bf16 — and the backend dispatch must be
semantics-free: a model configured with ``attn_backend="flash"`` decodes
token-identically to ``attn_backend="jnp"``, including the replicated
robust serving path under attack.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get as get_arch
from repro.kernels.decode_attention import decode_attention
from repro.models import attn_backend as AB
from repro.models import model as Mo
from repro.models.attention import mha

# ---------------------------------------------------------------- kernel


def _qkv(key, B, H, Hkv, dh, T, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, dh), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, dh), dtype)
    return q, k, v


# GQA 1:1 and 4:1, plus starcoder2's 36 heads (Hkv=4 -> group of 9)
@pytest.mark.parametrize("H,Hkv", [(4, 4), (8, 2), (36, 4)])
@pytest.mark.parametrize("kv_len", ["none", "scalar", "per_row"])
def test_decode_kernel_matches_mha(H, Hkv, kv_len):
    B, dh, T = 3, 32, 100
    q, k, v = _qkv(jax.random.PRNGKey(H * 100 + Hkv), B, H, Hkv, dh, T)
    lens = {"none": None, "scalar": jnp.asarray(37),
            "per_row": jnp.asarray([1, 42, 100])}[kv_len]
    got = decode_attention(q, k, v, kv_len=lens, interpret=True)
    want = mha(q, k, v, causal=False, window=None, chunk=1, kv_len=lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("blk_k", [16, 64, 4096])
def test_decode_kernel_tile_invariance(blk_k):
    """Wide interpret tile and narrow TPU-style tiles agree (padding
    beyond T rides the same validity mask as kv_len)."""
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 8, 2, 64, 200)
    lens = jnp.asarray([150, 200])
    got = decode_attention(q, k, v, kv_len=lens, blk_k=blk_k, interpret=True)
    want = mha(q, k, v, causal=False, window=None, chunk=1, kv_len=lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_decode_kernel_bf16():
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 8, 2, 64, 128, jnp.bfloat16)
    lens = jnp.asarray([77, 128])
    got = decode_attention(q, k, v, kv_len=lens, interpret=True)
    want = mha(q, k, v, causal=False, window=None, chunk=1, kv_len=lens)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_decode_kernel_rejects_multi_query():
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 4, 4, 32, 16)
    with pytest.raises(ValueError, match="single-query"):
        decode_attention(jnp.concatenate([q, q], axis=1), k, v)


# ------------------------------------------------------- model-level decode


def _decode_tokens(cfg, params, tokens, n, cache_len):
    """Greedy decode ``n`` tokens after prefilling ``tokens``."""
    _, caches = Mo.prefill(params, cfg, {"tokens": tokens},
                           cache_len=cache_len)
    tok = tokens[:, -1] * 0  # fixed first decode token
    out = []
    for _ in range(n):
        logits, caches = Mo.decode_step(params, cfg, caches, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, axis=1)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mixtral-8x7b",
                                  "whisper-medium"])
def test_flash_backend_token_identity(arch):
    """flash == jnp backends token-for-token through real decode stacks
    (mixtral exercises the ring/window cache, whisper the cross-attn
    decode path)."""
    cfg = get_arch(arch).reduced()
    params = Mo.init(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(3)
    batch = jax.random.randint(key, (2, 12), 0, cfg.vocab)
    toks = {}
    for backend in ("jnp", "flash"):
        c = dataclasses.replace(cfg, attn_backend=backend)
        if cfg.family == "encdec":
            frames = jax.random.normal(
                jax.random.PRNGKey(5),
                (2, cfg.encoder.n_frames, cfg.d_model), jnp.float32)
            _, caches = Mo.prefill(params, c, {"tokens": batch,
                                               "frames": frames},
                                   cache_len=24)
            tok = batch[:, -1] * 0
            out = []
            for _ in range(6):
                logits, caches = Mo.decode_step(params, c, caches, tok)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                out.append(tok)
            toks[backend] = jnp.stack(out, axis=1)
        else:
            toks[backend] = _decode_tokens(cfg=c, params=params,
                                           tokens=batch, n=6, cache_len=24)
    np.testing.assert_array_equal(np.asarray(toks["jnp"]),
                                  np.asarray(toks["flash"]))


def test_flash_full_attention_grad():
    """attn_backend='flash' under jax.grad: the custom-VJP wrapper
    differentiates the mha reference, so training configs can carry the
    flash backend. Gradients match the jnp backend closely."""
    cfg = get_arch("qwen3-1.7b").reduced()
    params = Mo.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab)}
    grads = {}
    for backend in ("jnp", "flash"):
        c = dataclasses.replace(cfg, attn_backend=backend)
        grads[backend] = jax.grad(lambda p: Mo.loss(p, c, batch))(params)
    for a, b in zip(jax.tree.leaves(grads["jnp"]),
                    jax.tree.leaves(grads["flash"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-4)


def test_full_flash_forward_matches_mha():
    """Force the full-seq flash path (as on TPU) and compare to mha."""
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 48, 8, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 48, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 48, 2, 32))
    got = AB._flash_full(True, 16)(q, k, v)
    want = mha(q, k, v, causal=True, window=None, chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_resolve_backend_policy():
    """Window and TP signatures are kernel-inexpressible -> jnp; decode
    auto resolves to flash everywhere; full-seq auto only on TPU."""
    assert AB.resolve_backend("jnp", decode=True) == "jnp"
    assert AB.resolve_backend("flash", decode=True) == "flash"
    assert AB.resolve_backend("flash", decode=False, window=64) == "jnp"
    assert AB.resolve_backend("auto", decode=True) == "flash"
    on_tpu = jax.default_backend() == "tpu"
    assert AB.resolve_backend("auto", decode=False) == (
        "flash" if on_tpu else "jnp")
    with pytest.raises(ValueError, match="unknown attn backend"):
        AB.resolve_backend("cuda", decode=True)


# ----------------------------------------------------- quantized KV cache

from repro.models.attention import quantize_kv


def test_quantize_kv_int8_roundtrip():
    """Symmetric per-(row, position) int8: round-trip error bounded by
    one quantization step of that position's own scale."""
    x = 3.0 * jax.random.normal(jax.random.PRNGKey(0), (2, 9, 4, 16))
    xi, s = quantize_kv(x, jnp.int8)
    assert xi.dtype == jnp.int8 and s.shape == (2, 9) and s.dtype == jnp.float32
    rt = xi.astype(jnp.float32) * s[:, :, None, None]
    step = jnp.max(jnp.abs(x), axis=(2, 3)) / 127.0
    assert float(jnp.max(jnp.abs(rt - x) - step[:, :, None, None])) <= 1e-6


def test_quantize_kv_bf16_cast():
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 2, 8))
    xb, s = quantize_kv(x, jnp.bfloat16)
    assert s is None and xb.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(xb, np.float32), np.asarray(x),
                               rtol=1e-2, atol=1e-2)


def test_decode_kernel_int8_fused_dequant():
    """int8 K/V with per-position scales inside the kernel == eager
    dequantize + f32 kernel (the dequant rides the block load)."""
    B, H, Hkv, dh, T = 3, 8, 2, 32, 60
    q, k, v = _qkv(jax.random.PRNGKey(3), B, H, Hkv, dh, T)
    lens = jnp.asarray([13, 60, 41])
    kq, ks = quantize_kv(k, jnp.int8)
    vq, vs = quantize_kv(v, jnp.int8)
    kd = kq.astype(jnp.float32) * ks[:, :, None, None]
    vd = vq.astype(jnp.float32) * vs[:, :, None, None]
    want = mha(q, kd, vd, causal=False, window=None, chunk=1, kv_len=lens)
    got = decode_attention(q, kq, vq, kv_len=lens, interpret=True,
                           k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # within quantization tolerance of the unquantized attention
    ref = mha(q, k, v, causal=False, window=None, chunk=1, kv_len=lens)
    assert float(jnp.max(jnp.abs(got - ref))) < 0.2


@pytest.mark.parametrize("blk_b", [1, 2, 3, 8])
def test_decode_kernel_batch_tiling(blk_b):
    """blk_b batch blocks (incl. zero-padding B=3 -> blk_b multiples)
    agree with the untiled kernel, with and without scales."""
    B, H, Hkv, dh, T = 3, 4, 2, 32, 48
    q, k, v = _qkv(jax.random.PRNGKey(4), B, H, Hkv, dh, T)
    lens = jnp.asarray([5, 48, 20])
    want = mha(q, k, v, causal=False, window=None, chunk=1, kv_len=lens)
    got = decode_attention(q, k, v, kv_len=lens, interpret=True,
                           blk_b=blk_b, blk_k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    kq, ks = quantize_kv(k, jnp.int8)
    vq, vs = quantize_kv(v, jnp.int8)
    got8 = decode_attention(q, kq, vq, kv_len=lens, interpret=True,
                            blk_b=blk_b, blk_k=16, k_scale=ks, v_scale=vs)
    base8 = decode_attention(q, kq, vq, kv_len=lens, interpret=True,
                             k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(got8), np.asarray(base8),
                               rtol=1e-5, atol=1e-5)


def test_decode_kernel_scale_validation():
    q, k, v = _qkv(jax.random.PRNGKey(5), 2, 4, 2, 32, 16)
    ks = jnp.ones((2, 16), jnp.float32)
    with pytest.raises(ValueError, match="scale"):
        decode_attention(q, k, v, k_scale=ks, interpret=True)


@pytest.mark.parametrize("kv,tol", [("bfloat16", 2e-2), ("int8", 0.25)])
def test_model_decode_quantized_kv(kv, tol):
    """Model-level: decode logits with a quantized cache stay within
    quantization tolerance, on both attention backends."""
    cfg = get_arch("qwen3-1.7b").reduced()
    cfg = dataclasses.replace(cfg, kv_dtype=kv)
    base = get_arch("qwen3-1.7b").reduced()
    params = Mo.init(jax.random.PRNGKey(0), base)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, base.vocab)
    for backend in ("jnp", "flash"):
        c_q = dataclasses.replace(cfg, attn_backend=backend)
        c_f = dataclasses.replace(base, attn_backend=backend)
        lq, cq = Mo.prefill(params, c_q, {"tokens": tokens}, cache_len=20)
        lf, cf = Mo.prefill(params, c_f, {"tokens": tokens}, cache_len=20)
        tok = jnp.argmax(lf[:, -1], -1).astype(jnp.int32)
        lq2, _ = Mo.decode_step(params, c_q, cq, tok)
        lf2, _ = Mo.decode_step(params, c_f, cf, tok)
        err = float(jnp.max(jnp.abs(lq2 - lf2)))
        assert err < tol * max(1.0, float(jnp.max(jnp.abs(lf2)))), (backend,
                                                                    err)
