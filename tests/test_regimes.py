"""Regime matrix: adaptive adversaries vs adaptive aggregation
(DESIGN.md §14).

The grid is {gaussian, signflip, wrong_value, alie, ipm, mimic} x
{median, vrmom, vrmom_adaptive, trimmed_mean, auto_gm} x alpha — every
robust arm must stay bounded in every regime while the mean control is
dragged by the loud attacks, and the *adaptive* arms must additionally
(a) estimate alpha online (the census), (b) recover the Byzantine
ranking where the §11 MAD-z suspicion is blind (S3), and (c) stay
bit-identical to their fixed baselines on honest data — adaptivity must
cost exactly nothing when there is nothing to adapt to.

The same matrix is driven through the production wires: the serve
m-replica token wire (greedy tokens identical to the honest decode),
the coverage harness (``assumed_alpha`` regime knob), and the sharded
train step (explicit ``AdaptiveState`` carry) in an 8-device
subprocess. ``benchmarks/regimes.py`` runs the full committed grid;
these tests pin the mechanisms.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adaptive as AD
# reprolint: disable=RL001 oracle: bit-identity tests compare adaptive arms against raw weiszfeld below the Estimator layer
from repro.core import aggregators as AG
from repro.core import attacks as A
from repro.core.estimator import Estimator
from repro.obs import diag as OD

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ATTACKS = ("gaussian", "signflip", "wrong_value", "alie", "ipm", "mimic")
ROBUST_ARMS = {
    "median": Estimator(method="median"),
    "vrmom": Estimator(method="vrmom", K=10),
    "vrmom_adaptive": Estimator(method="vrmom_adaptive", K=10),
    "trimmed_mean": Estimator(method="trimmed_mean", beta=0.25),
    "auto_gm": Estimator(method="auto_gm"),
}

W, C = 41, 40


MU = 2.0  # nonzero truth: a zero-mean truth would make signflip a
# near-no-op and ipm's payload vanish; mu=2 keeps signflip decisively
# loud (its payload sits at -mu, 2*mu from the center) for the S3
# exact-detection half.


def _stack(key=0):
    v = jax.random.normal(jax.random.PRNGKey(key), (W, C))
    return v + MU


def _attacked(attack, alpha, key=0):
    v = _stack(key)
    mask = A.byzantine_mask(W, alpha)
    return A.REGISTRY[attack](jax.random.PRNGKey(100 + key), v, mask), mask


def _err(agg):
    return float(jnp.linalg.norm(agg.astype(jnp.float32) - MU))


# ------------------------------------------------------ estimator-level matrix

@pytest.mark.parametrize("alpha", (0.1, 0.2))
@pytest.mark.parametrize("attack", ATTACKS)
def test_matrix_robust_arms_bounded(attack, alpha):
    """Every robust arm stays within a few honest standard errors of
    the truth, in every regime of the matrix."""
    v_att, _ = _attacked(attack, alpha)
    for name, est in ROBUST_ARMS.items():
        err = _err(est.apply(v_att, axis=0))
        assert err < 3.5, (attack, alpha, name, err)


@pytest.mark.parametrize("attack", ("signflip", "ipm", "wrong_value"))
def test_matrix_adaptive_beats_fixed_k(attack):
    """The tentpole contrast: at alpha=0.2 the fixed-K vrmom keeps its
    honest-regime K (its correction term amplifies the contamination
    drag), while the adaptive arms census the stack and either impute +
    drop K (vrmom_adaptive) or downweight (auto_gm) — strictly smaller
    error on the same attacked stack."""
    v_att, _ = _attacked(attack, 0.2)
    err_fixed = _err(ROBUST_ARMS["vrmom"].apply(v_att, axis=0))
    for name in ("vrmom_adaptive", "auto_gm"):
        err = _err(ROBUST_ARMS[name].apply(v_att, axis=0))
        assert err < err_fixed, (attack, name, err, err_fixed)


@pytest.mark.parametrize("attack", ("gaussian", "wrong_value"))
def test_matrix_mean_control_diverges(attack):
    """The contrast column: the unprotected mean is dragged far past
    every robust arm by the loud attacks at alpha=0.2."""
    v_att, _ = _attacked(attack, 0.2)
    err_mean = _err(jnp.mean(v_att, axis=0))
    worst_robust = max(_err(est.apply(v_att, axis=0))
                       for est in ROBUST_ARMS.values())
    assert err_mean > 2.0 * worst_robust + 1.0, (attack, err_mean)


@pytest.mark.parametrize("attack", ATTACKS)
def test_census_estimates_alpha_online(attack):
    """``estimate_alpha`` lands near the true contamination for every
    attack in the matrix — including the coordinated stealth attacks
    the §11 z-score alone cannot see (their identical payload rows trip
    the duplicate-multiplicity census instead)."""
    v_att, mask = _attacked(attack, 0.2)
    true_alpha = float(jnp.mean(mask.astype(jnp.float32)))
    a_hat = float(AD.estimate_alpha(v_att, axis=0))
    assert abs(a_hat - true_alpha) <= 0.1, (attack, a_hat, true_alpha)


def test_estimate_alpha_honest_is_exactly_zero():
    v = _stack()
    assert float(AD.estimate_alpha(v, axis=0)) == 0.0
    assert np.all(np.asarray(AD.worker_weights(v, axis=0)) == 1.0)


# ------------------------------------------------- honest-regime bit identity

def test_auto_gm_honest_bit_identical_to_geometric_median():
    v = _stack(key=3)
    np.testing.assert_array_equal(
        np.asarray(AD.auto_gm(v, axis=0)),
        np.asarray(AG.geometric_median(v, axis=0)))
    np.testing.assert_array_equal(
        np.asarray(Estimator(method="auto_gm").apply(v, axis=0)),
        np.asarray(AG.geometric_median(v, axis=0)))


def test_vrmom_adaptive_honest_bit_identical_to_vrmom():
    from repro.core.vrmom import vrmom

    v = _stack(key=4)
    np.testing.assert_array_equal(
        np.asarray(AD.vrmom_adaptive(v, K=10, axis=0)),
        np.asarray(vrmom(v, K=10, axis=0)))
    # Same-backend comparison: the adaptive tier runs on the jnp
    # backend, so the bit-identity claim is against the jnp vrmom (the
    # auto-resolved pallas kernel differs from jnp by 1 ulp on a few
    # coordinates, orthogonal to adaptivity).
    np.testing.assert_array_equal(
        np.asarray(Estimator(method="vrmom_adaptive", K=10).apply(v, axis=0)),
        np.asarray(Estimator(method="vrmom", K=10,
                             backend="jnp").apply(v, axis=0)))


def test_stateful_honest_bit_identical_and_state_fixed():
    """Unit weights are a fixed point of the EMA and momentum=0 is an
    exact passthrough: the stateful adaptive apply on honest stacks is
    bit-identical to the stateless one, for every step."""
    est = Estimator(method="auto_gm")
    state = est.init_adaptive_state(W, C)
    for k in range(3):
        v = _stack(key=10 + k)
        out, state = est.apply_adaptive(v, state, axis=0)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(est.apply(v, axis=0)))
        assert np.all(np.asarray(state.weights) == 1.0)
        assert float(state.alpha_hat) == 0.0
        assert int(state.step) == k + 1


def test_k_ladder_select():
    assert AD.k_ladder(10) == (10, 5, 1)
    assert AD.k_ladder(1) == (1,)
    assert float(AD.select_k(jnp.float32(0.0), 10)) == 10.0
    assert float(AD.select_k(jnp.float32(0.1), 10)) == 5.0
    assert float(AD.select_k(jnp.float32(0.3), 10)) == 1.0


def test_census_constants_match_obs_diag():
    """§11 parity: the census and the telemetry suspicion machinery use
    the same z-score convention — they must never drift apart."""
    assert AD.Z_THRESH == OD._Z_THRESH
    assert AD.REL_FLOOR == OD._REL_FLOOR


# ---------------------------------------------- S3: suspicion degradation

@pytest.mark.parametrize("attack", ("gaussian", "signflip"))
def test_mad_z_suspicion_exact_on_loud_attacks(attack):
    """The §11 MAD-z census alone identifies loud attackers exactly at
    alpha=0.25: suspected == the true Byzantine mask."""
    v_att, mask = _attacked(attack, 0.25)
    # reprolint: disable=RL001 diagnose() takes a precomputed center; raw median is the documented §11 pairing
    d = OD.diagnose(v_att, jnp.median(v_att, axis=0))
    np.testing.assert_array_equal(np.asarray(d.suspected), np.asarray(mask))


@pytest.mark.parametrize("attack", ("alie", "mimic"))
def test_mad_z_suspicion_blind_to_stealth_attacks(attack):
    """The degradation half of S3: the same MAD-z census flags NOTHING
    under alie/mimic at alpha=0.25 — the payloads sit inside the honest
    deviation spread."""
    v_att, _ = _attacked(attack, 0.25)
    # reprolint: disable=RL001 diagnose() takes a precomputed center; raw median is the documented §11 pairing
    d = OD.diagnose(v_att, jnp.median(v_att, axis=0))
    assert not bool(jnp.any(d.suspected)), attack


def test_auto_gm_weights_recover_stealth_ranking():
    """The recovery half of S3: auto_gm's census weights rank the
    stealth attackers below every honest worker (alie), or confine them
    to the lowest-weight duplicate cluster (mimic, where the mimicked
    victim is indistinguishable collateral by construction)."""
    v_att, mask = _attacked("alie", 0.25)
    w = np.asarray(AD.worker_weights(v_att, axis=0))
    m = np.asarray(mask)
    assert w[m].max() < w[~m].min(), (w[m].max(), w[~m].min())

    v_att, mask = _attacked("mimic", 0.25)
    w = np.asarray(AD.worker_weights(v_att, axis=0))
    m = np.asarray(mask)
    n_byz = int(m.sum())
    lowest = np.argsort(w)[: n_byz + 1]
    assert set(np.where(m)[0]).issubset(set(lowest))


# ------------------------------------------------------------ serve wire

@pytest.mark.parametrize("method", ("vrmom", "vrmom_adaptive", "auto_gm",
                                    "median"))
def test_serve_token_identity_under_attack(method):
    """m=8 replica wire at alpha=0.25 under the gaussian attack: every
    robust arm (fixed and adaptive) serves greedy tokens identical to
    the honest decode; the mean control serves corrupted tokens."""
    from repro.serve import RobustDecodeConfig, Sampling
    from repro.serve import robust as Ro

    B, V, m = 4, 64, 8
    honest = jax.random.normal(jax.random.PRNGKey(21), (B, V))
    logits_r = jnp.broadcast_to(honest[None], (m, B, V))
    want = np.asarray(jnp.argmax(honest, axis=-1).astype(jnp.int32))
    sc = Sampling(method="greedy")
    skey = jax.random.PRNGKey(0)

    rcfg = RobustDecodeConfig(m=m, estimator=method, K=8,
                              attack="gaussian", alpha=0.25)
    tok = Ro.robust_sample(logits_r, rcfg, jax.random.PRNGKey(5), skey, sc)
    np.testing.assert_array_equal(np.asarray(tok), want, err_msg=method)

    mcfg = RobustDecodeConfig(m=m, estimator="mean",
                              attack="gaussian", alpha=0.25)
    tok_mean = Ro.robust_sample(logits_r, mcfg, jax.random.PRNGKey(5),
                                skey, sc)
    assert np.any(np.asarray(tok_mean) != want), "control not corrupted"


# ----------------------------------------------------------- coverage wire

def test_coverage_assumed_alpha_narrows_ci():
    """The regime-matrix knob: an analyst assuming alpha=0 gets strictly
    narrower CIs than the oracle that inflates for the true alpha=0.2 —
    the width deficit is exactly what the fixed arms lose coverage to in
    BENCH_regimes.json."""
    from repro.infer.coverage import coverage_run

    kw = dict(model="linear", attack="alie", alpha=0.2, estimator="vrmom",
              K=5, reps=8, N_per_machine=100, m_workers=20, p=3, rounds=3,
              batch_size=4, seed=7)
    w_naive = float(jnp.mean(coverage_run(assumed_alpha=0.0, **kw).width))
    w_oracle = float(jnp.mean(coverage_run(assumed_alpha=0.2, **kw).width))
    assert w_naive < w_oracle, (w_naive, w_oracle)


def test_coverage_wire_accepts_adaptive_estimator():
    from repro.infer.coverage import coverage_run

    cell = coverage_run(model="linear", attack="alie", alpha=0.2,
                        estimator="auto_gm", reps=8, N_per_machine=100,
                        m_workers=20, p=3, rounds=3, batch_size=4, seed=7)
    s = cell.summary()
    assert np.isfinite(s["rmse"])
    assert s["coverage"] >= 0.5, s


# ---------------------------------------------------------- dist/train wire

def test_stacked_adaptive_wire_honest_matches_stateless():
    from repro.dist import robust_reduce as RR

    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 4, 6)) + 1.0,
         "b": jax.random.normal(jax.random.PRNGKey(1), (8, 5)) + 1.0}
    est = Estimator(method="auto_gm")
    dim = sum(x.size // 8 for x in g.values())
    out, state = RR.aggregate_stacked_adaptive(
        g, est.init_adaptive_state(8, dim), est)
    direct = RR.aggregate_stacked_auto(g, est)
    for k in g:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(direct[k]))
    assert np.all(np.asarray(state.weights) == 1.0)
    assert float(state.alpha_hat) == 0.0


def test_train_step_adaptive_state_carry_8dev():
    """Sharded train step with an adaptive estimator: the AdaptiveState
    rides the jitted step as an explicit carry (RL211), the loss stays
    finite under ipm, and the honest-regime state stays at the unit
    fixed point bit-exactly."""
    script = """
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import get as get_arch
from repro.data import lm_batch, shard_batch
from repro.dist import sharding as S
from repro.models import model as M
from repro.train.step import make_train_step
import repro.optim as O

mesh = jax.make_mesh((8, 1), ("data", "model"))
cfg = get_arch("qwen3-1.7b").reduced()
setup = make_train_step(cfg, mesh, estimator="auto_gm",
                        byzantine_frac=0.15, attack="ipm", lr=1e-2,
                        microbatch=1)
assert setup.init_state is not None
st = setup.init_state()
assert st.weights.shape == (8,)
opt = O.get(cfg.optimizer, lr=1e-2)
params = M.init(jax.random.PRNGKey(0), cfg)
p = jax.device_put(params, S.to_named(mesh, setup.params_specs))
os_ = jax.jit(opt.init)(p)
step = jax.jit(setup.step_fn)
for i in range(3):
    b = shard_batch(lm_batch(cfg, i, 8, 32), mesh, setup.batch_axes)
    p, os_, loss, st = step(p, os_, b, jax.random.PRNGKey(i), st)
    assert np.isfinite(float(loss))
assert int(st.step) == 3
print("ADAPTIVE-STEP-OK")

setup_h = make_train_step(cfg, mesh, estimator="vrmom_adaptive",
                          byzantine_frac=0.0, attack="gaussian", lr=1e-2,
                          microbatch=1)
sth = setup_h.init_state()
b = shard_batch(lm_batch(cfg, 0, 8, 32), mesh, setup_h.batch_axes)
p2, os2, l2, sth = jax.jit(setup_h.step_fn)(p, os_, b,
                                            jax.random.PRNGKey(0), sth)
assert float(sth.weights.min()) == 1.0 and float(sth.alpha_hat) == 0.0
print("HONEST-STATE-OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "ADAPTIVE-STEP-OK" in r.stdout and "HONEST-STATE-OK" in r.stdout
