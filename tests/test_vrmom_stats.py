"""Statistical validation of the VRMOM estimator against the paper's theory."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import vrmom as V
# reprolint: disable=RL001 unit under test: this file validates the aggregator layer itself against the paper's theory
from repro.core import aggregators, attacks
from repro.core.estimator import Estimator


def test_sigma_k_sq_matches_theory():
    # K=1 reduces to the median: sigma_1^2 = (1/4)/psi(0)^2 = pi/2.
    assert V.sigma_k_sq(1) == pytest.approx(math.pi / 2, rel=1e-6)
    # Monotone decreasing in K, limiting value pi/3 (Theorem 1).
    vals = [V.sigma_k_sq(k) for k in (1, 2, 5, 10, 50, 200)]
    assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))
    assert vals[-1] == pytest.approx(math.pi / 3, rel=2e-2)
    # K=5 already gives efficiency > 0.9 (paper Section 2.1).
    assert 1.0 / V.sigma_k_sq(5) > 0.9


def test_deltas_symmetric():
    d = np.asarray(V.deltas(10))
    np.testing.assert_allclose(d, -d[::-1], atol=1e-6)
    assert np.all(np.diff(d) > 0)


def _simulate(key, reps, m1, n, estimator):
    """Simulate sample means directly: Xbar_j ~ N(0, 1/n) exactly."""
    xbar = jax.random.normal(key, (reps, m1)) / jnp.sqrt(n)
    return jax.vmap(estimator)(xbar)


def test_vrmom_variance_reduction_matches_theorem1():
    # Monte-Carlo: Var(VRMOM)/Var(MOM) should approach sigma_K^2 / (pi/2).
    key = jax.random.PRNGKey(0)
    reps, m1, n, K = 4000, 101, 1000, 10
    est_v = _simulate(key, reps, m1, n, lambda x: V.vrmom(x, K=K, scale="mad"))
    est_m = _simulate(key, reps, m1, n, lambda x: V.mom(x))
    var_ratio = float(jnp.var(est_v) / jnp.var(est_m))
    theory = V.sigma_k_sq(K) / V.sigma_mom_sq()
    assert var_ratio == pytest.approx(theory, rel=0.15)
    # And VRMOM strictly better than MOM.
    assert float(jnp.var(est_v)) < float(jnp.var(est_m))


def test_vrmom_master_scale_consistent():
    key = jax.random.PRNGKey(1)
    k1, k2 = jax.random.split(key)
    m1, n = 101, 1000
    raw = 2.0 + 3.0 * jax.random.normal(k1, (m1, n))
    xbar = jnp.mean(raw, axis=1)
    est = V.vrmom(xbar, K=10, scale="master", master_samples=raw[0])
    assert abs(float(est) - 2.0) < 0.05


def test_vrmom_byzantine_robust():
    key = jax.random.PRNGKey(2)
    m1, n = 101, 1000
    xbar = jax.random.normal(key, (m1,)) / jnp.sqrt(n)
    mask = attacks.byzantine_mask(m1, 0.3)
    corrupted = attacks.gaussian(jax.random.PRNGKey(3), xbar, mask)
    est = V.vrmom(corrupted, K=10, scale="mad")
    # Remark 2: correction bounded by s * K/2 / sum psi; estimate stays near 0.
    assert abs(float(est)) < 10.0 / math.sqrt(n)
    # mean is destroyed by the same corruption
    assert abs(float(jnp.mean(corrupted))) > 10 * abs(float(est))


def test_vrmom_multidim_coordinatewise():
    key = jax.random.PRNGKey(4)
    xbar = jax.random.normal(key, (33, 7, 5))
    out = V.vrmom(xbar, K=10)
    assert out.shape == (7, 5)
    col = V.vrmom(xbar[:, 3, 2], K=10)
    np.testing.assert_allclose(np.asarray(out[3, 2]), np.asarray(col), rtol=1e-5)


def test_vrmom_constant_input_returns_median():
    xbar = jnp.full((17,), 3.25)
    assert float(V.vrmom(xbar)) == pytest.approx(3.25)


def test_vrmom_degenerate_scale_fallback_no_nan():
    """All-equal inputs give MAD scale 0; the eps guard must return the
    exact median with no NaN — including per-coordinate, when only SOME
    coordinates are degenerate (the RRS zero-padding path hits this)."""
    from repro.kernels import ref as kref

    # fully degenerate, including the all-zero wire-padding case
    for c in (0.0, -7.5, 1e-20):
        out = V.vrmom(jnp.full((9, 4), c, jnp.float32), K=10)
        assert bool(jnp.all(jnp.isfinite(out)))
        np.testing.assert_allclose(np.asarray(out), np.float32(c),
                                   rtol=0, atol=0)

    # mixed: column 0 constant, column 1 spread
    key = jax.random.PRNGKey(0)
    spread = jax.random.normal(key, (9,))
    x = jnp.stack([jnp.full((9,), 2.0), spread], axis=1)
    out = V.vrmom(x, K=10)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(out[0]) == pytest.approx(2.0, abs=0)
    # the non-degenerate coordinate still gets the full correction
    np.testing.assert_allclose(
        float(out[1]), float(V.vrmom(spread, K=10)), rtol=1e-6)

    # the kernel oracle shares the same guard
    kout = kref.ref_vrmom(jnp.zeros((5, 8)), K=10)
    assert bool(jnp.all(jnp.isfinite(kout)))
    np.testing.assert_allclose(np.asarray(kout), 0.0, rtol=0, atol=0)


def test_aggregators_registry_shapes():
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (12, 6))
    for name in aggregators.REGISTRY:
        kw = {"n_byzantine": 2} if name == "krum" else {}
        out = Estimator(method=name, **kw).apply(x)
        assert out.shape == (6,), name
        assert bool(jnp.all(jnp.isfinite(out))), name


def test_trimmed_mean_robust():
    x = jnp.concatenate([jnp.ones((18, 4)), 1e6 * jnp.ones((2, 4))])
    # reprolint: disable=RL001 unit under test: trimmed_mean robustness oracle, below the Estimator layer by design
    out = aggregators.trimmed_mean(x, beta=0.15)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-6)


def test_trimmed_mean_zero_trim_warns():
    """int(beta*m)==0 degrades to the mean — the function must warn
    (the Estimator spec upgrades this to a trace-time error)."""
    x = jnp.ones((8, 4))
    with pytest.warns(RuntimeWarning, match="0 rows"):
        # reprolint: disable=RL001 unit under test: the warning path only exists below the Estimator layer
        aggregators.trimmed_mean(x, beta=0.1)


def test_theorem4_multivariate_normality_covariance():
    """Theorem 4 (+ Prop. 1): Monte-Carlo covariance of the multivariate
    VRMOM/MOM estimators matches C / C_MOM (eq. 13/14/17), and
    C <= C_MOM (Remark 4)."""
    p_dim, rho, K = 2, 0.6, 10
    Sigma = np.array([[1.0, rho], [rho, 1.0]])
    C = V.vrmom_asymptotic_cov(Sigma, K)
    C_mom = V.mom_asymptotic_cov(Sigma)
    # diagonal consistency with the 1-D theory
    assert C[0, 0] == pytest.approx(V.sigma_k_sq(K), rel=1e-6)
    assert C_mom[0, 0] == pytest.approx(math.pi / 2, rel=1e-6)
    # Remark 4: C_MOM - C positive definite
    eigs = np.linalg.eigvalsh(C_mom - C)
    assert np.all(eigs > 0)

    # Monte-Carlo: machine means ~ N(0, Sigma/n) exactly
    m1, n, reps = 101, 1000, 3000
    L = np.linalg.cholesky(Sigma)
    key = jax.random.PRNGKey(0)
    z = jax.random.normal(key, (reps, m1, p_dim))
    xbar = jnp.einsum("rmp,qp->rmq", z, jnp.asarray(L)) / jnp.sqrt(n)
    est_v = jax.vmap(lambda x: V.vrmom(x, K=K, scale="mad"))(xbar)
    est_m = jax.vmap(V.mom)(xbar)
    N = m1 * n
    cov_v = np.cov(np.asarray(est_v).T) * N
    cov_m = np.cov(np.asarray(est_m).T) * N
    np.testing.assert_allclose(cov_v, np.asarray(C), rtol=0.2, atol=0.08)
    np.testing.assert_allclose(cov_m, np.asarray(C_mom), rtol=0.2, atol=0.12)
