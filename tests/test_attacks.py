"""Registry-wide property tests for the Byzantine attack zoo.

Every attack in ``core.attacks.REGISTRY`` must behave as a *message
corruption*: same stack shape and dtype out, the trusted master (row 0)
untouched under the standard ``byzantine_mask``, and a strict no-op
when no row is marked Byzantine. A new attack that breaks any of these
silently corrupts honest rows — which would invalidate every robustness
claim downstream — so the properties are asserted over the whole
registry, not per attack.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks as A

DTYPES = (jnp.float32, jnp.bfloat16)


def _stack(dtype, key=0):
    return jax.random.normal(jax.random.PRNGKey(key), (9, 33)).astype(dtype)


@pytest.mark.parametrize("name", sorted(A.REGISTRY))
@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_attack_preserves_shape_and_dtype(name, dtype):
    v = _stack(dtype)
    mask = A.byzantine_mask(v.shape[0], 0.25)
    out = A.REGISTRY[name](jax.random.PRNGKey(1), v, mask)
    assert out.shape == v.shape, (name, out.shape)
    assert out.dtype == v.dtype, (name, out.dtype)
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32)))), name


@pytest.mark.parametrize("name", sorted(A.REGISTRY))
def test_attack_never_corrupts_master_row(name):
    v = _stack(jnp.float32)
    for alpha in (0.1, 0.25, 0.49):
        mask = A.byzantine_mask(v.shape[0], alpha)
        assert not bool(mask[0]), "byzantine_mask marked the master"
        out = A.REGISTRY[name](jax.random.PRNGKey(2), v, mask)
        np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(v[0]),
                                      err_msg=f"{name} corrupted row 0")


@pytest.mark.parametrize("name", sorted(A.REGISTRY))
def test_attack_noop_under_all_false_mask(name):
    v = _stack(jnp.float32)
    out = A.REGISTRY[name](jax.random.PRNGKey(3),
                           v, jnp.zeros(v.shape[0], bool))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(v),
                                  err_msg=f"{name} is not a no-op")


def test_attack_jit_and_vmap_compose():
    """Attacks are pure (key, v, mask) functions — they must survive a
    jit and a leading vmap unchanged (the train step vmaps per-leaf)."""
    v = _stack(jnp.float32)
    mask = A.byzantine_mask(v.shape[0], 0.25)
    for name, fn in sorted(A.REGISTRY.items()):
        eager = fn(jax.random.PRNGKey(4), v, mask)
        jitted = jax.jit(fn)(jax.random.PRNGKey(4), v, mask)
        np.testing.assert_allclose(np.asarray(eager), np.asarray(jitted),
                                   rtol=1e-6, atol=1e-6, err_msg=name)


def test_alie_sits_inside_honest_spread():
    """ALIE's whole point: corrupt rows land within a z-score of the
    honest cloud (evading naive trimming), unlike ``omniscient`` whose
    payload is 1e10x the honest mean."""
    v = _stack(jnp.float32, key=7)
    mask = A.byzantine_mask(v.shape[0], 0.25)
    out = A.alie(jax.random.PRNGKey(5), v, mask)
    h = np.asarray(v)[~np.asarray(mask)]
    z = (np.asarray(out)[-1] - h.mean(0)) / h.std(0)
    # one shared z per coordinate, and a modest one
    assert np.allclose(z, z[0], atol=1e-4), "z varies across coordinates"
    assert 0.0 < z[0] < 3.0, z[0]
    # the corrupt rows are all identical (coordinated attack)
    np.testing.assert_array_equal(np.asarray(out)[-1], np.asarray(out)[-2])


def test_alie_explicit_z_override():
    v = _stack(jnp.float32)
    mask = A.byzantine_mask(v.shape[0], 0.25)
    out = A.alie(jax.random.PRNGKey(5), v, mask, z=1.5)
    h = np.asarray(v)[~np.asarray(mask)]
    z = (np.asarray(out)[-1] - h.mean(0)) / h.std(0)
    assert np.allclose(z, 1.5, atol=1e-3), z


@pytest.mark.parametrize("n", (2, 3, 4, 5, 9))
def test_alie_default_z_boundary_n(n):
    """S2 regression: the default z must stay finite and *non-zero* for
    tiny/even stacks. The old floor(n/2+1)-quantile default degenerated
    for n <= 3 (quantile -> 1, z -> inf or nan) and pinned z near 0 for
    n in (4, 5); the supported-rank default keeps a strictly positive,
    finite payload offset at every n >= 2."""
    v = jax.random.normal(jax.random.PRNGKey(n), (n, 7))
    n_byz = max(int(0.25 * n), 1)
    mask = jnp.arange(n) >= (n - n_byz)
    out = A.alie(jax.random.PRNGKey(1), v, mask)
    assert bool(jnp.all(jnp.isfinite(out))), n
    # the payload must actually move the corrupted rows (z > 0 strictly)
    assert not np.array_equal(np.asarray(out)[-1], np.asarray(v)[-1]), n
    # and honest rows stay untouched
    np.testing.assert_array_equal(np.asarray(out)[0], np.asarray(v)[0])


def test_ipm_payload_is_negative_scaled_honest_mean():
    """IPM (inner-product manipulation): every Byzantine row reports
    ``-eps * mean(honest)`` so the aggregate's inner product with the
    true descent direction is driven negative."""
    v = _stack(jnp.float32, key=13)
    mask = A.byzantine_mask(v.shape[0], 0.25)
    out = np.asarray(A.ipm(jax.random.PRNGKey(6), v, mask, eps=0.5))
    h = np.asarray(v)[~np.asarray(mask)]
    np.testing.assert_allclose(out[-1], -0.5 * h.mean(0), rtol=1e-5)
    np.testing.assert_array_equal(out[-1], out[-2])  # coordinated


def test_mimic_clones_an_honest_worker():
    """Mimic: all Byzantine rows re-broadcast one *honest* row verbatim
    (the most-deviant one — maximally skews any weighted aggregate
    toward that outlier while every reported value stays legitimate)."""
    v = _stack(jnp.float32, key=17)
    mask = np.asarray(A.byzantine_mask(v.shape[0], 0.25))
    out = np.asarray(A.mimic(jax.random.PRNGKey(7), v, mask))
    byz_rows = out[mask]
    honest = np.asarray(v)[~mask]
    # every corrupt row equals the same single honest row
    np.testing.assert_array_equal(byz_rows[0], byz_rows[-1])
    assert any(np.array_equal(byz_rows[0], h) for h in honest)


def test_alie_is_stealthy_where_omniscient_is_not():
    """ALIE payloads stay inside the honest 3-sigma envelope (that is
    the attack: evade distance-based filtering); omniscient payloads
    leave it by ~10 orders of magnitude."""
    key = jax.random.PRNGKey(11)
    v = jax.random.normal(key, (9, 257))
    mask = A.byzantine_mask(9, 0.25)
    h = np.asarray(v)[~np.asarray(mask)]
    lo, hi = h.mean(0) - 3 * h.std(0), h.mean(0) + 3 * h.std(0)
    stealthy = np.asarray(A.alie(key, v, mask))[-1]
    assert np.all((lo <= stealthy) & (stealthy <= hi))
    loud = np.asarray(A.omniscient(key, v, mask))[-1]
    assert np.any((loud < lo) | (loud > hi))
