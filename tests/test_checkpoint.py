"""Checkpoint round-trips, incl. the bf16 view(uint16) storage path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt


def test_bf16_roundtrip_bit_exact(tmp_path):
    """bf16 leaves are stored as raw uint16 bits (npz has no bf16);
    restore must reproduce them bit-exactly alongside other dtypes."""
    key = jax.random.PRNGKey(0)
    tree = {
        "w": jax.random.normal(key, (7, 5), jnp.float32).astype(jnp.bfloat16),
        "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                   "s": jax.random.normal(key, (3,), jnp.float32)},
    }
    path = str(tmp_path / "ck")
    ckpt.save(path, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    out = ckpt.restore(path, like)
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(out["w"]).view(np.uint16),
        np.asarray(tree["w"]).view(np.uint16))
    np.testing.assert_array_equal(np.asarray(out["nested"]["b"]),
                                  np.asarray(tree["nested"]["b"]))
    np.testing.assert_array_equal(np.asarray(out["nested"]["s"]),
                                  np.asarray(tree["nested"]["s"]))


def test_bf16_roundtrip_extreme_values(tmp_path):
    """Values that would be mangled by a float32 round-trip (NaN payloads
    aside): denormals, infs, and the bf16 max survive the bit view."""
    vals = np.array([0.0, -0.0, np.inf, -np.inf, 3.3895314e38,  # bf16 max
                     1e-38, -1e-38], np.float32)
    tree = {"x": jnp.asarray(vals).astype(jnp.bfloat16)}
    path = str(tmp_path / "ck")
    ckpt.save(path, tree)
    out = ckpt.restore(path, {"x": jnp.zeros((7,), jnp.bfloat16)})
    np.testing.assert_array_equal(
        np.asarray(out["x"]).view(np.uint16),
        np.asarray(tree["x"]).view(np.uint16))
