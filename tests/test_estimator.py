"""Unified Estimator layer: backend parity + spec validation.

The acceptance contract of DESIGN.md §7: ``jnp``, ``ref`` and ``pallas``
(interpret mode on CPU) must agree to 1e-5 for every supported method,
across odd/even worker counts, flat ``[m, C]`` and batched ``[m, B, V]``
stacks, and through the degenerate-scale VRMOM guard; whole-vector
estimators must be rejected for coordinate-wise/chunked use at trace
time rather than producing wrong shards.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.estimator import (BACKENDS, COORDINATEWISE_METHODS,
                                  WHOLE_VECTOR_METHODS, Estimator)

PARITY_BACKENDS = ("jnp", "ref", "pallas")


def _spec(method, m):
    kw = {}
    if method == "trimmed_mean":
        kw["beta"] = 0.2  # int(0.2*m) >= 1 for every m under test
    if method == "vrmom":
        kw["K"] = 8
    return Estimator(method=method, interpret=True, **kw)


def _rand(key, shape):
    return 4.0 * jax.random.normal(key, shape, jnp.float32) + 1.5


# ---------------------------------------------------------------------------
# Backend parity (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [7, 8, 16, 33])  # odd and even worker counts
@pytest.mark.parametrize("method", COORDINATEWISE_METHODS)
def test_backend_parity_flat(method, m):
    x = _rand(jax.random.PRNGKey(m), (m, 257))
    outs = [np.asarray(_spec(method, m)._replace(backend=b).apply(x))
            for b in PARITY_BACKENDS]
    for got in outs[1:]:
        np.testing.assert_allclose(got, outs[0], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m", [5, 8])
@pytest.mark.parametrize("method", COORDINATEWISE_METHODS)
def test_backend_parity_batched_logits(method, m):
    """[m, B, V] replica-logit stacks — the serve wire tensor."""
    x = _rand(jax.random.PRNGKey(100 + m), (m, 4, 97))
    outs = [np.asarray(_spec(method, m)._replace(backend=b).apply(x))
            for b in PARITY_BACKENDS]
    assert outs[0].shape == (4, 97)
    for got in outs[1:]:
        np.testing.assert_allclose(got, outs[0], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", PARITY_BACKENDS)
def test_vrmom_degenerate_scale_guard_all_backends(backend):
    """Constant columns (MAD = 0) must return the exact median — no NaN,
    no correction — on every backend; mixed constant/spread columns get
    the guard per coordinate."""
    est = Estimator(method="vrmom", backend=backend, interpret=True)
    const = jnp.full((8, 33), -3.25, jnp.float32)
    np.testing.assert_array_equal(np.asarray(est.apply(const)),
                                  np.full((33,), -3.25, np.float32))
    spread = _rand(jax.random.PRNGKey(0), (8,))
    x = jnp.stack([jnp.full((8,), 2.0), spread], axis=1)
    out = np.asarray(est.apply(x))
    assert np.all(np.isfinite(out))
    assert out[0] == np.float32(2.0)
    want = Estimator(method="vrmom", backend="jnp").apply(spread[:, None])
    np.testing.assert_allclose(out[1], np.asarray(want)[0], rtol=1e-5)


def test_auto_backend_resolution():
    assert Estimator(method="vrmom").resolve_backend() == "pallas"
    assert Estimator(method="trimmed_mean").resolve_backend() == "pallas"
    assert Estimator(method="mean").resolve_backend() == "ref"  # no sort
    assert Estimator(method="krum").resolve_backend() == "jnp"
    assert Estimator(method="median", backend="ref").resolve_backend() == "ref"


def test_estimator_is_jit_static():
    """Specs are hashable NamedTuples: usable as jit static args."""
    agg_static = jax.jit(lambda x, est: est.apply(x), static_argnums=1)
    x = _rand(jax.random.PRNGKey(2), (8, 64))
    e = Estimator(method="median", interpret=True)
    np.testing.assert_allclose(np.asarray(agg_static(x, e)),
                               # reprolint: disable=RL001 reference oracle: this test validates Estimator dispatch against raw jnp.median
                               np.asarray(jnp.median(x, axis=0)),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Spec validation (satellite: beta vs m; whole-vector rejection)
# ---------------------------------------------------------------------------

def test_trimmed_mean_beta_validated_at_trace_time():
    x = jnp.ones((8, 4))
    with pytest.raises(ValueError, match="degrades to the mean"):
        Estimator(method="trimmed_mean", beta=0.1).apply(x)
    # the same spec is fine at m=16 (int(0.1*16) = 1)
    Estimator(method="trimmed_mean", beta=0.1, interpret=True).apply(
        jnp.ones((16, 4)))
    with pytest.raises(ValueError, match="nothing left"):
        Estimator(method="trimmed_mean", beta=0.5).validate(8)


@pytest.mark.parametrize("method", WHOLE_VECTOR_METHODS)
def test_whole_vector_rejected_for_chunked_use(method):
    est = Estimator(method=method)
    with pytest.raises(ValueError, match="whole-vector"):
        est.require_coordinatewise()
    for backend in ("ref", "pallas"):
        with pytest.raises(ValueError, match="whole-vector"):
            est._replace(backend=backend).apply(jnp.ones((8, 4)))


@pytest.mark.parametrize("method", WHOLE_VECTOR_METHODS)
def test_whole_vector_rejected_by_rrs_and_serve(method):
    """The RRS wire format and the replica-logit aggregation both refuse
    whole-vector estimators with a clear error instead of producing
    wrong shards (DESIGN.md §7)."""
    from repro.dist import robust_reduce as RR
    from repro.serve.robust import RobustDecodeConfig

    g = {"w": jnp.ones((4, 8))}
    with pytest.raises(ValueError, match="whole-vector"):
        RR.aggregate_stacked_auto(g, method)
    with pytest.raises(ValueError, match="whole-vector"):
        RobustDecodeConfig(m=8, estimator=method)


def test_whole_vector_still_usable_unchunked():
    """On a full stacked vector (the statistical path) the whole-vector
    estimators remain first-class via the jnp backend."""
    x = _rand(jax.random.PRNGKey(3), (9, 40))
    for method in WHOLE_VECTOR_METHODS:
        out = Estimator(method=method, n_byzantine=2).apply(x)
        assert out.shape == (40,)
        assert bool(jnp.all(jnp.isfinite(out)))


def test_robust_decode_config_coercion():
    from repro.serve.robust import RobustDecodeConfig

    r = RobustDecodeConfig(m=8, estimator="trimmed_mean", alpha=0.25)
    assert isinstance(r.estimator, Estimator)
    assert r.estimator.beta == 0.25  # bound to alpha, not the 0.1 default
    r2 = RobustDecodeConfig(m=8, estimator="vrmom", K=4)
    assert r2.estimator.K == 4
    explicit = Estimator(method="median")
    assert RobustDecodeConfig(m=8, estimator=explicit).estimator is explicit
    with pytest.raises(ValueError, match="degrades to the mean"):
        RobustDecodeConfig(m=8, estimator=Estimator(method="trimmed_mean",
                                                    beta=0.1))


def test_unknown_method_and_backend():
    with pytest.raises(ValueError, match="unknown estimator method"):
        Estimator(method="winsorized").apply(jnp.ones((4, 4)))
    with pytest.raises(ValueError, match="unknown backend"):
        Estimator(backend="tpu").apply(jnp.ones((4, 4)))
    with pytest.raises(TypeError):
        Estimator.coerce(42)


def test_coerce_passthrough_and_defaults():
    e = Estimator(method="median", backend="ref")
    assert Estimator.coerce(e) is e
    c = Estimator.coerce("vrmom", K=3)
    assert (c.method, c.K) == ("vrmom", 3)


# ---------------------------------------------------------------------------
# Non-zero axis + dtype behavior
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", PARITY_BACKENDS)
def test_apply_nonzero_axis(backend):
    x = _rand(jax.random.PRNGKey(4), (3, 8, 5))
    est = Estimator(method="median", backend=backend, interpret=True)
    out = est.apply(x, axis=1)
    # reprolint: disable=RL001 reference oracle: nonzero-axis dispatch validated against raw jnp.median
    want = jnp.median(x, axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", ("ref", "pallas"))
def test_fused_backends_preserve_dtype(backend):
    x = _rand(jax.random.PRNGKey(5), (8, 64)).astype(jnp.bfloat16)
    out = Estimator(method="vrmom", backend=backend, interpret=True).apply(x)
    assert out.dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# Fused aggregation + sampling dispatch (DESIGN.md §12)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", sorted(COORDINATEWISE_METHODS))
def test_apply_sample_backend_parity(method):
    """apply_sample: the pallas fused tail and the jnp fallback return
    the same greedy token and the same aggregate for every
    coordinate-wise method (mean has no fused kernel — the dispatch
    falls through to apply + argmax and must still agree)."""
    x = _rand(jax.random.PRNGKey(7), (8, 3, 97))
    outs = {}
    for backend in ("pallas", "jnp"):
        est = Estimator(method=method, backend=backend, interpret=True,
                        beta=0.2)
        agg, tok = est.apply_sample(x)
        outs[backend] = (np.asarray(agg), np.asarray(tok))
    np.testing.assert_allclose(outs["pallas"][0], outs["jnp"][0],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(outs["pallas"][1], outs["jnp"][1])


def test_apply_sample_topk_parity():
    x = _rand(jax.random.PRNGKey(8), (8, 2, 120))
    for backend in ("pallas", "jnp"):
        est = Estimator(method="vrmom", backend=backend, interpret=True)
        agg, topv, topi = est.apply_sample(x, top_k=4)
        want_v, want_i = jax.lax.top_k(agg, 4)
        np.testing.assert_array_equal(np.asarray(topi), np.asarray(want_i))
        np.testing.assert_allclose(np.asarray(topv), np.asarray(want_v),
                                   rtol=1e-6, atol=1e-6)
        assert topi.dtype == jnp.int32


def test_apply_sample_with_agg_false():
    """with_agg=False: fused path skips the aggregate write (None);
    the token still matches the with_agg=True dispatch."""
    x = _rand(jax.random.PRNGKey(9), (8, 2, 64))
    est = Estimator(method="vrmom", backend="pallas", interpret=True)
    agg, tok = est.apply_sample(x)
    no_agg, tok2 = est.apply_sample(x, with_agg=False)
    assert no_agg is None
    np.testing.assert_array_equal(np.asarray(tok), np.asarray(tok2))


def test_apply_sample_rejects_non_stack():
    est = Estimator(method="vrmom", interpret=True)
    with pytest.raises(ValueError, match="m, B, V"):
        est.apply_sample(_rand(jax.random.PRNGKey(0), (8, 64)))
