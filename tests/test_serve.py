"""repro.serve: slot cache, fused decode loop, continuous batching,
Byzantine-robust replicated decoding."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get as get_arch
from repro.models import model as Mo
from repro.serve import (Request, RobustDecodeConfig, Sampling, Scheduler,
                         ServeEngine, replica_mask, robust_logits)

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def dense():
    cfg = get_arch("qwen3-1.7b").reduced()
    params = Mo.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompt_batch(cfg, B, S, seed=1):
    return {"tokens": jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0,
                                         cfg.vocab)}


# ---------------------------------------------------------------------------
# Engine: scanned decode loop
# ---------------------------------------------------------------------------

def test_scanned_loop_matches_python_loop(dense):
    """The fused lax.scan decode must be token-identical to per-step
    Python dispatch (greedy)."""
    cfg, params = dense
    eng = ServeEngine(cfg, params, max_len=48)
    batch = _prompt_batch(cfg, B=4, S=16)
    scan = eng.generate(batch, 12)
    loop = eng.generate_python_loop(batch, 12)
    assert scan.shape == (4, 12)
    np.testing.assert_array_equal(np.asarray(scan), np.asarray(loop))


def test_sampling_modes(dense):
    """Temperature / top-k sampling produce in-vocab tokens and differ
    across keys; top-k=1 degenerates to greedy."""
    cfg, params = dense
    eng = ServeEngine(cfg, params, max_len=40)
    batch = _prompt_batch(cfg, B=2, S=8)
    t = eng.generate(batch, 8, sampling=Sampling("temperature", 1.5),
                     key=jax.random.PRNGKey(3))
    assert bool(jnp.all((t >= 0) & (t < cfg.vocab)))
    t2 = eng.generate(batch, 8, sampling=Sampling("temperature", 1.5),
                      key=jax.random.PRNGKey(4))
    assert not bool(jnp.all(t == t2))  # different keys, different draws
    k1 = eng.generate(batch, 8, sampling=Sampling("top_k", 1.0, top_k=1),
                      key=jax.random.PRNGKey(5))
    greedy = eng.generate(batch, 8)
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(greedy))


# ---------------------------------------------------------------------------
# Scheduler: continuous batching (satellite coverage)
# ---------------------------------------------------------------------------

def test_scheduler_variable_length_admission(dense):
    """Variable-length prompts through the pool must match per-request
    solo decode exactly (per-slot lengths isolate the rows)."""
    cfg, params = dense
    eng = ServeEngine(cfg, params, max_len=64, n_slots=3)
    sched = Scheduler(eng, decode_block=4)
    rs = np.random.RandomState(0)
    prompts = [rs.randint(0, cfg.vocab, size=(n,)) for n in (5, 17, 11)]
    uids = [sched.submit(Request(tokens=p, max_new_tokens=7))
            for p in prompts]
    done = sched.run()
    assert sorted(done) == sorted(uids)
    for u, p in zip(uids, prompts):
        solo = eng.generate({"tokens": jnp.asarray(p)[None]}, 7)
        assert done[u].tokens == list(map(int, solo[0]))
        assert done[u].finished_by == "length"


def test_scheduler_slot_reuse_after_retirement(dense):
    """A slot freed by a short request must be reused mid-decode by a
    queued one, without disturbing the still-running slots."""
    cfg, params = dense
    eng = ServeEngine(cfg, params, max_len=64, n_slots=2)
    sched = Scheduler(eng, decode_block=2)
    rs = np.random.RandomState(1)
    short = Request(tokens=rs.randint(0, cfg.vocab, size=(6,)),
                    max_new_tokens=2)
    long = Request(tokens=rs.randint(0, cfg.vocab, size=(9,)),
                   max_new_tokens=12)
    late = Request(tokens=rs.randint(0, cfg.vocab, size=(4,)),
                   max_new_tokens=8)
    uids = [sched.submit(r) for r in (short, long, late)]
    # only 2 slots: `late` waits until `short` retires, then decodes
    # alongside `long`, which must be unaffected.
    done = sched.run()
    assert sorted(done) == sorted(uids)
    for u, r in zip(uids, (short, long, late)):
        assert len(done[u].tokens) == r.max_new_tokens
        solo = eng.generate({"tokens": jnp.asarray(r.tokens)[None]},
                            r.max_new_tokens)
        assert done[u].tokens == list(map(int, solo[0]))


def test_scheduler_queue_starvation(dense):
    """More requests than slots: FIFO admission drains the whole queue."""
    cfg, params = dense
    eng = ServeEngine(cfg, params, max_len=48, n_slots=2)
    sched = Scheduler(eng, decode_block=3)
    rs = np.random.RandomState(2)
    uids = [sched.submit(Request(tokens=rs.randint(0, cfg.vocab, size=(4 + i,)),
                                 max_new_tokens=3))
            for i in range(7)]
    done = sched.run()
    assert sorted(done) == sorted(uids)
    assert all(len(done[u].tokens) == 3 for u in uids)


def test_scheduler_rejects_oversized_requests(dense):
    """A request whose prompt + budget cannot fit a slot is rejected
    onto completed (not crashed, not silently cache-corrupted), and the
    queue behind it still drains."""
    cfg, params = dense
    eng = ServeEngine(cfg, params, max_len=24, n_slots=1)
    sched = Scheduler(eng, decode_block=2)
    rs = np.random.RandomState(4)
    big = sched.submit(Request(tokens=rs.randint(0, cfg.vocab, size=(40,)),
                               max_new_tokens=4))
    tight = sched.submit(Request(tokens=rs.randint(0, cfg.vocab, size=(10,)),
                                 max_new_tokens=20))  # 10+20+1 > 24
    ok = sched.submit(Request(tokens=rs.randint(0, cfg.vocab, size=(10,)),
                              max_new_tokens=4))
    done = sched.run()
    assert done[big].finished_by == "rejected" and done[big].tokens == []
    assert done[tight].finished_by == "rejected"
    assert done[ok].finished_by == "length" and len(done[ok].tokens) == 4


def test_engine_capacity_check(dense):
    cfg, params = dense
    eng = ServeEngine(cfg, params, max_len=24)
    batch = _prompt_batch(cfg, B=1, S=20)
    with pytest.raises(ValueError, match="cache slots"):
        eng.generate(batch, 10)


def test_scheduler_eos_trims_overshoot(dense):
    """EOS mid-block stops the sequence; overshoot tokens are trimmed."""
    cfg, params = dense
    eng = ServeEngine(cfg, params, max_len=48, n_slots=1)
    # find the token greedy decode emits at step 2, use it as "EOS"
    probe = eng.generate(_prompt_batch(cfg, B=1, S=8, seed=9), 8)
    eos = int(probe[0, 2])
    sched = Scheduler(eng, decode_block=8)
    tokens = np.asarray(_prompt_batch(cfg, B=1, S=8, seed=9)["tokens"][0])
    uid = sched.submit(Request(tokens=tokens, max_new_tokens=8, eos_id=eos))
    done = sched.run()
    assert done[uid].finished_by == "eos"
    assert done[uid].tokens == list(map(int, probe[0, :3]))


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "zamba2-7b",
                                  "whisper-medium"])
def test_pool_decode_other_families(arch):
    """Slot pool + per-slot positions across cache layouts (SSM state,
    hybrid grouped stacks, enc-dec cross caches)."""
    cfg = get_arch(arch).reduced()
    params = Mo.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_len=40, n_slots=2)
    sched = Scheduler(eng, decode_block=2)
    rs = np.random.RandomState(3)
    reqs = []
    for i in range(3):
        extras = None
        if cfg.family == "encdec":
            extras = {"frames": rs.randn(cfg.encoder.n_frames,
                                         cfg.d_model).astype(np.float32)}
        reqs.append(Request(tokens=rs.randint(0, cfg.vocab, size=(5 + 3 * i,)),
                            max_new_tokens=4, extras=extras))
    uids = [sched.submit(r) for r in reqs]
    done = sched.run()
    for u, r in zip(uids, reqs):
        batch = {"tokens": jnp.asarray(r.tokens)[None]}
        if r.extras:
            batch.update({k: jnp.asarray(v)[None]
                          for k, v in r.extras.items()})
        solo = eng.generate(batch, 4)
        assert done[u].tokens == list(map(int, solo[0]))


# ---------------------------------------------------------------------------
# Robust replicated decoding (acceptance criterion)
# ---------------------------------------------------------------------------

def test_replica_mask_counts():
    mask = replica_mask(8, 0.25)
    assert int(mask.sum()) == 2 and not bool(mask[0])
    with pytest.raises(ValueError):
        replica_mask(8, 0.5)  # 4/8 corrupted: no honest majority


@pytest.mark.parametrize("attack", ["signflip", "gaussian"])
@pytest.mark.parametrize("aggregator", ["vrmom", "median", "trimmed_mean"])
def test_robust_decode_token_identical_under_attack(dense, attack,
                                                    aggregator):
    """floor(alpha*m)=2 of m=8 replicas corrupted: greedy replicated
    decode must be token-identical to single-replica decode."""
    cfg, params = dense
    batch = _prompt_batch(cfg, B=2, S=12)
    plain = ServeEngine(cfg, params, max_len=40).generate(batch, 10)
    kw = dict(K=8) if aggregator == "vrmom" else {}
    reng = ServeEngine(cfg, params, max_len=40,
                       robust=RobustDecodeConfig(
                           m=8, estimator=aggregator, attack=attack,
                           alpha=0.25, **kw))
    robust = reng.generate(batch, 10, key=jax.random.PRNGKey(11))
    np.testing.assert_array_equal(np.asarray(robust), np.asarray(plain))


def test_robust_flash_backend_token_identical_under_attack(dense):
    """Fused end-to-end decode (kernel attention + kernel aggregation,
    DESIGN.md §8): attn_backend='flash' with m=8 replicated decode under
    signflip must still be token-identical to plain single-replica
    decode — the backend changes execution, never tokens."""
    cfg, params = dense
    batch = _prompt_batch(cfg, B=2, S=12)
    plain = ServeEngine(cfg, params, max_len=40,
                        attn_backend="jnp").generate(batch, 10)
    reng = ServeEngine(cfg, params, max_len=40, attn_backend="flash",
                       robust=RobustDecodeConfig(m=8, estimator="vrmom", K=8,
                                                 attack="signflip",
                                                 alpha=0.25))
    robust = reng.generate(batch, 10, key=jax.random.PRNGKey(11))
    np.testing.assert_array_equal(np.asarray(robust), np.asarray(plain))


def test_mean_aggregation_breaks_under_attack(dense):
    """Control: non-robust mean aggregation is corrupted by an attack
    the robust aggregators survive (omniscient: the corrupted rows drag
    the mean to a huge negative multiple of the honest logits)."""
    cfg, params = dense
    batch = _prompt_batch(cfg, B=2, S=12)
    plain = ServeEngine(cfg, params, max_len=40).generate(batch, 10)
    meng = ServeEngine(cfg, params, max_len=40,
                       robust=RobustDecodeConfig(m=8, estimator="mean",
                                                 attack="omniscient",
                                                 alpha=0.25))
    mean_toks = meng.generate(batch, 10, key=jax.random.PRNGKey(11))
    assert not bool(jnp.all(mean_toks == plain))


def test_robust_pool_decode_token_identical_under_attack(dense):
    """Continuous batching + replicated decode: the pool path flattens
    replicas into the slot dim per decode block (and restores them for
    admit/evict) — completions must still match plain solo decode under
    attack, across mid-decode admissions."""
    cfg, params = dense
    plain = ServeEngine(cfg, params, max_len=64, n_slots=2)
    reng = ServeEngine(cfg, params, max_len=64, n_slots=2,
                       robust=RobustDecodeConfig(m=4, estimator="vrmom", K=8,
                                                 attack="signflip",
                                                 alpha=0.25))
    sched = Scheduler(reng, decode_block=3)
    rs = np.random.RandomState(7)
    reqs = [Request(tokens=rs.randint(0, cfg.vocab, size=(5 + 2 * i,)),
                    max_new_tokens=6) for i in range(3)]
    uids = [sched.submit(r) for r in reqs]
    done = sched.run()
    assert sorted(done) == sorted(uids)
    for u, r in zip(uids, reqs):
        solo = plain.generate({"tokens": jnp.asarray(r.tokens)[None]}, 6)
        assert done[u].tokens == list(map(int, solo[0]))


def test_flatten_unflatten_replicas_roundtrip(dense):
    """flatten_replicas is a bijection on replica-stacked cache trees."""
    from repro.serve.robust import (flatten_replicas, stack_replicas,
                                    unflatten_replicas)
    from repro.serve import cache as C

    cfg, params = dense
    eng = ServeEngine(cfg, params, max_len=32, n_slots=3)
    dims = C.slot_dims(eng._pool_caches)
    caches = eng._pool_caches(3)
    rep = stack_replicas(caches, 4)
    flat = flatten_replicas(rep, dims, 4)
    back = unflatten_replicas(flat, dims, 4)
    for a, b in zip(jax.tree.leaves(rep), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_robust_logits_exactness():
    """With identical honest rows, the aggregate IS the honest row
    bit-exactly (degenerate-scale guard makes VRMOM the median)."""
    key = jax.random.PRNGKey(0)
    honest = jax.random.normal(key, (3, 32))
    stacked = jnp.broadcast_to(honest[None], (8,) + honest.shape)
    rcfg = RobustDecodeConfig(m=8, estimator="vrmom", K=8,
                              attack="gaussian", alpha=0.25)
    agg = robust_logits(stacked, rcfg, key=jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(agg), np.asarray(honest))


# ---------------------------------------------------------------------------
# Sharded pool smoke (cache_specs plug-in), subprocess with 8 devices
# ---------------------------------------------------------------------------

def test_pool_specs_shard_and_decode():
    """Pool sharded via serve.cache.pool_specs on a (4 data, 2 model)
    mesh decodes token-identically to the unsharded pool."""
    script = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get as get_arch
from repro.dist import ctx as CTX, sharding as S
from repro.models import model as Mo
from repro.serve import Request, Scheduler, ServeEngine
from repro.serve import cache as C

cfg = get_arch("qwen3-1.7b").reduced()
params = Mo.init(jax.random.PRNGKey(0), cfg)
eng = ServeEngine(cfg, params, max_len=32, n_slots=4)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, cfg.vocab)}
want = np.stack([np.asarray(
    eng.generate({"tokens": batch["tokens"][i:i+1]}, 6))[0]
    for i in range(4)])

mesh = jax.make_mesh((4, 2), ("data", "model"))
pool = eng.make_pool()
specs = C.pool_specs(cfg, pool, mesh, batch_axes=("data",))
named = S.to_named(mesh, specs)
pool = jax.tree.map(lambda s, x: jax.device_put(x, s), named, pool,
                    is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding))
for slot in range(4):
    pool, tok = eng.admit(pool, slot, {"tokens": batch["tokens"][slot:slot+1]})
    assert tok == int(want[slot, 0]), (slot, tok, want[slot, 0])
cur = np.asarray(want[:, 0], np.int32)
with CTX.mesh_context(mesh):
    pool, toks = eng.decode_pool(pool, cur, 5)
got = np.concatenate([cur[:, None], np.asarray(toks).T], axis=1)
np.testing.assert_array_equal(got, want)
print("SHARDED-POOL-OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    assert "SHARDED-POOL-OK" in r.stdout


# ---------------------------------------------------------------------------
# Fused robust-decode tail (DESIGN.md §12)
# ---------------------------------------------------------------------------

from repro.core.estimator import Estimator
from repro.serve import robust as Ro


@pytest.mark.parametrize("m", [4, 8])
@pytest.mark.parametrize("method", ["median", "mom", "trimmed_mean",
                                    "vrmom"])
@pytest.mark.parametrize("alpha,attack", [(0.0, "none"), (0.25, "signflip"),
                                          (0.25, "gaussian")])
def test_fused_robust_sample_greedy_identity(dense, m, method, alpha, attack):
    """robust_sample with fuse_tail on/off: greedy tokens bit-identical
    across every estimator x replica count x attack cell (logit level —
    the model forward is shared, so this isolates the tail)."""
    cfg, _ = dense
    est = Estimator(method=method,
                    beta=0.25 if method == "trimmed_mean" else 0.1)
    logits_r = 4.0 * jax.random.normal(
        jax.random.PRNGKey(m), (m, 3, cfg.vocab), jnp.float32)
    akey, skey = jax.random.split(jax.random.PRNGKey(2))
    sc = Sampling()  # greedy
    tok_f = Ro.robust_sample(
        logits_r, RobustDecodeConfig(m=m, alpha=alpha, attack=attack,
                                     estimator=est, fuse_tail=True),
        akey, skey, sc)
    tok_u = Ro.robust_sample(
        logits_r, RobustDecodeConfig(m=m, alpha=alpha, attack=attack,
                                     estimator=est, fuse_tail=False),
        akey, skey, sc)
    np.testing.assert_array_equal(np.asarray(tok_f), np.asarray(tok_u))


def test_fused_engine_greedy_identity(dense):
    """End-to-end: fused vs unfused engines emit identical greedy tokens
    through prefill + the scanned decode loop under attack."""
    cfg, params = dense
    batch = _prompt_batch(cfg, B=4, S=12)
    toks = {}
    for fused in (True, False):
        eng = ServeEngine(cfg, params, max_len=32, robust=RobustDecodeConfig(
            m=8, alpha=0.25, attack="signflip", estimator="vrmom",
            fuse_tail=fused))
        toks[fused] = np.asarray(eng.generate(batch, 8,
                                              key=jax.random.PRNGKey(3)))
    np.testing.assert_array_equal(toks[True], toks[False])


def test_fused_topk_sampling_distribution(dense):
    """Fused top-k tail samples from the same distribution as the
    unfused path: over many keys, per-position token histograms agree
    within sampling noise (the kernels share values but draw through
    differently-shaped gumbel tensors, so tokens differ per-key)."""
    cfg, params = dense
    logits_r = 4.0 * jax.random.normal(jax.random.PRNGKey(0),
                                       (4, 2, cfg.vocab), jnp.float32)
    sc = Sampling("top_k", temperature=1.0, top_k=5)
    # 256 iid draws per original batch row by tiling the batch axis:
    # the sampling epilogue draws per-row gumbels, so tiled rows are
    # independent repeats of the same two distributions.
    reps = 256
    big = jnp.tile(logits_r, (1, reps, 1))  # [4, reps*2, V]
    draws = {}
    for fused in (True, False):
        rcfg = RobustDecodeConfig(m=4, alpha=0.0, attack="none",
                                  estimator="vrmom", fuse_tail=fused)
        akey, skey = jax.random.split(jax.random.PRNGKey(1))
        draws[fused] = np.asarray(
            Ro.robust_sample(big, rcfg, akey, skey, sc)).reshape(reps, 2)
    # same support, against the aggregate rcfg actually builds
    # (__post_init__ pins VRMOM's K, so a bare Estimator would differ)
    agg = Ro.robust_logits(logits_r, rcfg)
    top5 = np.asarray(jax.lax.top_k(agg, 5)[1])
    for d in draws.values():
        for b in range(2):
            assert set(np.unique(d[:, b])) <= set(top5[b])
    # distributions agree: total-variation distance over the top-5
    # support within Monte-Carlo noise for 256 draws
    for b in range(2):
        pf = np.array([(draws[True][:, b] == t).mean() for t in top5[b]])
        pu = np.array([(draws[False][:, b] == t).mean() for t in top5[b]])
        assert 0.5 * np.abs(pf - pu).sum() < 0.15, (b, pf, pu)


def test_deterministic_loop_skips_key_split(dense):
    """Greedy + attack='none' decode consumes no randomness: any key
    yields the same tokens (the per-step threefry split is elided)."""
    cfg, params = dense
    eng = ServeEngine(cfg, params, max_len=32,
                      robust=RobustDecodeConfig(m=4, estimator="vrmom"))
    batch = _prompt_batch(cfg, B=2, S=8)
    a = np.asarray(eng.generate(batch, 8, key=jax.random.PRNGKey(0)))
    b = np.asarray(eng.generate(batch, 8, key=jax.random.PRNGKey(99)))
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# Quantized KV cache in the serve path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv", ["bfloat16", "int8"])
def test_engine_quantized_kv_token_identity(dense, kv):
    """Greedy tokens survive KV quantization on a short horizon (the
    reduced model's logit margins dwarf bf16/int8 rounding)."""
    cfg, params = dense
    batch = _prompt_batch(cfg, B=4, S=10)
    ref_t = np.asarray(ServeEngine(cfg, params, max_len=24)
                       .generate(batch, 6))
    got = np.asarray(ServeEngine(cfg, params, max_len=24, kv_dtype=kv)
                     .generate(batch, 6))
    assert (ref_t == got).mean() > 0.9, kv


def test_pool_decode_quantized_kv(dense):
    """Continuous batching at bf16 KV: scheduler completes mixed-length
    requests with the same tokens as the f32 pool."""
    cfg, params = dense

    def run(kv):
        eng = ServeEngine(cfg, params, max_len=24, n_slots=3, kv_dtype=kv)
        sched = Scheduler(eng, sampling=Sampling())
        batch = _prompt_batch(cfg, B=3, S=10)
        for i in range(3):
            sched.submit(Request(tokens=np.asarray(batch["tokens"][i][:6 + i]),
                                 max_new_tokens=5))
        return {rid: np.asarray(r.tokens) for rid, r in sched.run().items()}

    ref_t, got = run(None), run("bfloat16")
    assert sorted(ref_t) == sorted(got)
    same = [np.array_equal(ref_t[r], got[r]) for r in ref_t]
    assert np.mean(same) >= 2 / 3, same


def test_kv_bytes_per_slot_gauge(dense):
    """serve.kv_bytes_per_slot reports the quantization win: bf16 halves
    and int8 (data + f32 scales) cuts ~4x the f32 per-slot bytes."""
    from repro.obs import MetricsRegistry
    cfg, params = dense
    g = {}
    for kv in (None, "bfloat16", "int8"):
        reg = MetricsRegistry()
        ServeEngine(cfg, params, max_len=32, kv_dtype=kv, obs=reg)
        g[kv] = reg.snapshot()["gauges"]["serve.kv_bytes_per_slot"]
    assert g[None] > g["bfloat16"] > g["int8"] > 0
    assert abs(g["bfloat16"] / g[None] - 0.5) < 0.05
    assert g["int8"] < 0.35 * g[None]


def test_robust_engine_quantized_kv(dense):
    """Replica-stacked pool slots carry quantized KV too: the
    replicated emulation's per-slot bytes scale by m, the shared one's
    don't, and both decode the same tokens over a bf16 cache."""
    from repro.obs import MetricsRegistry
    cfg, params = dense
    toks, gauges = {}, {}
    for shared in (True, False):
        reg = MetricsRegistry()
        eng = ServeEngine(cfg, params, max_len=24, kv_dtype="bfloat16",
                          robust=RobustDecodeConfig(
                              m=4, alpha=0.25, attack="signflip",
                              estimator="vrmom",
                              share_replica_compute=shared),
                          obs=reg)
        batch = _prompt_batch(cfg, B=2, S=8)
        toks[shared] = np.asarray(eng.generate(batch, 6,
                                               key=jax.random.PRNGKey(0)))
        gauges[shared] = reg.snapshot()["gauges"]["serve.kv_bytes_per_slot"]
    np.testing.assert_array_equal(toks[True], toks[False])
    assert gauges[False] == 4 * gauges[True]


@pytest.mark.parametrize("alpha,attack", [(0.0, "none"), (0.25, "signflip"),
                                          (0.25, "gaussian")])
def test_shared_replica_compute_token_identity(dense, alpha, attack):
    """The shared-compute emulation's equivalence claim: one forward
    broadcast into the wire stack decodes bit-identically to executing
    every replica's forward, across attacks (the attack corrupts the
    logit stack, never replica state)."""
    cfg, params = dense
    batch = _prompt_batch(cfg, B=3, S=10)
    toks = {}
    for shared in (True, False):
        eng = ServeEngine(cfg, params, max_len=24, robust=RobustDecodeConfig(
            m=8, alpha=alpha, attack=attack, estimator="vrmom",
            share_replica_compute=shared))
        toks[shared] = np.asarray(eng.generate(batch, 8,
                                               key=jax.random.PRNGKey(4)))
    np.testing.assert_array_equal(toks[True], toks[False])


def test_shared_replica_compute_pool_identity(dense):
    """Same equivalence through the scheduler pool path: plain-shaped
    robust slots decode the tokens the [m, ...]-stacked pool does."""
    cfg, params = dense

    def run(shared):
        eng = ServeEngine(cfg, params, max_len=24, n_slots=2,
                          robust=RobustDecodeConfig(
                              m=4, alpha=0.25, attack="signflip",
                              estimator="vrmom",
                              share_replica_compute=shared))
        sched = Scheduler(eng, decode_block=3)
        batch = _prompt_batch(cfg, B=2, S=10)
        uids = [sched.submit(Request(tokens=np.asarray(batch["tokens"][i]),
                                     max_new_tokens=5)) for i in range(2)]
        done = sched.run()
        return {u: done[u].tokens for u in uids}

    a, b = run(True), run(False)
    assert a == b
