"""Per-kernel allclose tests: Pallas (interpret on CPU) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import vrmom as V
from repro.kernels import ref
from repro.kernels.vrmom import (aggregate_pallas, mom_pallas,
                                 trimmed_mean_pallas, vrmom_pallas)


def _rand(key, m, c, dtype):
    x = 4.0 * jax.random.normal(key, (m, c), jnp.float32) + 1.5
    return x.astype(dtype)


SHAPES = [(3, 7), (8, 64), (16, 512), (17, 513), (32, 1000), (33, 2048),
          (2, 5), (101, 300)]


@pytest.mark.parametrize("m,c", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mom_kernel_matches_ref(m, c, dtype):
    x = _rand(jax.random.PRNGKey(m * 1000 + c), m, c, dtype)
    got = mom_pallas(x, interpret=True)
    want = ref.ref_mom(x)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("m,c", SHAPES)
@pytest.mark.parametrize("K", [1, 5, 10, 16])
def test_vrmom_kernel_matches_ref(m, c, K):
    x = _rand(jax.random.PRNGKey(m + c + K), m, c, jnp.float32)
    got = vrmom_pallas(x, K=K, interpret=True)
    want = ref.ref_vrmom(x, K=K)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vrmom_kernel_dtypes(dtype):
    x = _rand(jax.random.PRNGKey(0), 16, 777, dtype)
    got = vrmom_pallas(x, K=10, interpret=True)
    want = ref.ref_vrmom(x, K=10)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)
    assert got.dtype == dtype


def test_ref_matches_core_estimator():
    """The kernel oracle must equal the statistical reference (core.vrmom)."""
    x = _rand(jax.random.PRNGKey(3), 21, 40, jnp.float32)
    a = ref.ref_vrmom(x, K=10)
    b = V.vrmom(x, K=10, scale="mad")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_kernel_nd_input():
    x = _rand(jax.random.PRNGKey(4), 16, 6 * 9, jnp.float32).reshape(16, 6, 9)
    got = aggregate_pallas(x, "vrmom", interpret=True)
    want = ref.ref_vrmom(x.reshape(16, -1)).reshape(6, 9)
    assert got.shape == (6, 9)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@pytest.mark.parametrize("m,c", [(8, 64), (17, 513), (32, 1000)])
@pytest.mark.parametrize("beta", [0.15, 0.25])
def test_trimmed_mean_kernel_matches_ref(m, c, beta):
    """The trim rides the same sorting network: static slice of the
    sorted block must equal the jnp sort-and-slice oracle."""
    x = _rand(jax.random.PRNGKey(m + c), m, c, jnp.float32)
    got = trimmed_mean_pallas(x, beta=beta, interpret=True)
    want = ref.ref_trimmed_mean(x, beta=beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("m,c", [(3, 7), (8, 64), (33, 2048)])
def test_mean_kernel_matches_ref(m, c):
    x = _rand(jax.random.PRNGKey(m * 7 + c), m, c, jnp.float32)
    got = aggregate_pallas(x, "mean", interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.ref_mean(x)),
                               rtol=2e-6, atol=2e-6)


def test_kernel_byzantine_bounded():
    key = jax.random.PRNGKey(5)
    x = _rand(key, 32, 128, jnp.float32)
    y = x.at[-10:].set(1e9)  # 10/32 Byzantine rows
    got = vrmom_pallas(y, K=10, interpret=True)
    med = ref.ref_mom(x[:-10])
    assert float(jnp.max(jnp.abs(got - med))) < 50.0


# ---------------------------------------------------------------- flash attn

from repro.kernels.flash_attention import flash_attention


@pytest.mark.parametrize("S,H,Hkv,dh,blk", [
    (64, 2, 2, 32, 16), (96, 4, 2, 64, 32), (128, 2, 1, 64, 64),
    (80, 2, 2, 32, 32),  # non-divisible seq
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(S, H, Hkv, dh, blk, causal):
    key = jax.random.PRNGKey(S + H)
    B = 2
    q = jax.random.normal(key, (B, S, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, dh))
    got = flash_attention(q, k, v, causal=causal, blk_q=blk, blk_k=blk,
                          interpret=True)
    kk = jnp.repeat(k, H // Hkv, axis=2)
    vv = jnp.repeat(v, H // Hkv, axis=2)
    want = ref.ref_attention(q, kk, vv, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("S,T", [(64, 100), (33, 47), (16, 1500)])
def test_flash_attention_noncausal_padded_keys(S, T):
    """Non-causal with tile-indivisible T (the cross-attention shape,
    e.g. whisper's F=1500 encoder cache): the static in-kernel
    key-validity mask must cover the padded kv block — this used to
    silently fall back to the jnp reference instead of running the
    kernel."""
    B, H, dh = 2, 4, 32
    q = jax.random.normal(jax.random.PRNGKey(S), (B, S, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(T), (B, T, H, dh))
    v = jax.random.normal(jax.random.PRNGKey(S + T), (B, T, H, dh))
    got = flash_attention(q, k, v, causal=False, blk_q=32, blk_k=32,
                          interpret=True)
    want = ref.ref_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_flash_attention_bf16():
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (1, 64, 2, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 2, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 2, 64), jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, blk_q=32, blk_k=32,
                          interpret=True)
    want = ref.ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)
    assert got.dtype == jnp.bfloat16


def test_flash_attention_matches_model_mha():
    """Flash kernel == the model's chunked mha (same math)."""
    from repro.models.attention import mha
    key = jax.random.PRNGKey(4)
    B, S, H, dh = 2, 64, 4, 32
    q = jax.random.normal(key, (B, S, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(5), (B, S, 2, dh))
    v = jax.random.normal(jax.random.PRNGKey(6), (B, S, 2, dh))
    a = flash_attention(q, k, v, causal=True, blk_q=16, blk_k=16,
                        interpret=True)
    b = mha(q, k, v, causal=True, window=None, chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------- fused decode tail

from repro.kernels.vrmom import aggregate_sample_pallas


def _stack(key, m, B, V):
    return 4.0 * jax.random.normal(key, (m, B, V), jnp.float32) + 1.5


@pytest.mark.parametrize("m", [4, 8])
@pytest.mark.parametrize("method", ["median", "mom", "trimmed_mean",
                                    "vrmom"])
def test_fused_tail_greedy_bit_identical(m, method):
    """One-dispatch agg+argmax == aggregate kernel + jnp argmax, bitwise."""
    beta = 0.25 if method == "trimmed_mean" else 0.1
    x = _stack(jax.random.PRNGKey(m), m, 3, 257)
    agg, tok = aggregate_sample_pallas(x, method=method, beta=beta,
                                       interpret=True)
    want_agg = aggregate_pallas(x, method=method, beta=beta, interpret=True)
    assert (np.asarray(agg) == np.asarray(want_agg)).all()
    assert (np.asarray(tok)
            == np.asarray(jnp.argmax(want_agg, axis=-1))).all()
    assert tok.dtype == jnp.int32


@pytest.mark.parametrize("k", [1, 5, 16])
def test_fused_tail_topk_matches_lax(k):
    """Fused top-k epilogue reproduces jax.lax.top_k values AND order."""
    x = _stack(jax.random.PRNGKey(k), 8, 2, 300)
    agg, topv, topi = aggregate_sample_pallas(x, method="vrmom", top_k=k,
                                              interpret=True)
    want_v, want_i = jax.lax.top_k(agg, k)
    assert (np.asarray(topv) == np.asarray(want_v)).all()
    assert (np.asarray(topi) == np.asarray(want_i)).all()


def test_fused_tail_topk_tie_order():
    """Duplicate maxima resolve to the smaller index, like lax.top_k."""
    x = jnp.zeros((4, 1, 64), jnp.float32).at[:, 0, 10].set(7.0)
    x = x.at[:, 0, 3].set(7.0)
    agg, topv, topi = aggregate_sample_pallas(x, method="median", top_k=2,
                                              interpret=True)
    want_v, want_i = jax.lax.top_k(agg, 2)
    assert (np.asarray(topi) == np.asarray(want_i)).all()
    assert list(np.asarray(topi[0])) == [3, 10]


def test_fused_tail_with_agg_false():
    """with_agg=False skips the [B, V] HBM write, same token."""
    x = _stack(jax.random.PRNGKey(9), 8, 4, 200)
    agg, tok = aggregate_sample_pallas(x, method="vrmom", interpret=True)
    none_agg, tok2 = aggregate_sample_pallas(x, method="vrmom",
                                             interpret=True, with_agg=False)
    assert none_agg is None
    assert (np.asarray(tok) == np.asarray(tok2)).all()


def test_fused_tail_multi_tile():
    """Vocab split across tiles: running argmax carries across grid steps."""
    x = _stack(jax.random.PRNGKey(11), 8, 2, 513)
    a1, t1 = aggregate_sample_pallas(x, method="vrmom", tile=128,
                                     interpret=True)
    a2, t2 = aggregate_sample_pallas(x, method="vrmom", interpret=True)
    assert (np.asarray(a1) == np.asarray(a2)).all()
    assert (np.asarray(t1) == np.asarray(t2)).all()


def test_fused_tail_byzantine_bounded():
    """floor(alpha m) saturated rows cannot move the greedy token.

    The honest stack votes coordinate 17 with a margin far above the
    estimator's worst-case displacement under 2/8 corrupted rows, so
    the fused token must survive the attack.
    """
    key = jax.random.PRNGKey(5)
    x = _stack(key, 8, 2, 128).at[:, :, 17].add(1e3)
    y = x.at[-2:].set(1e9)  # 2/8 Byzantine rows
    agg, tok = aggregate_sample_pallas(y, method="vrmom", interpret=True)
    med = ref.ref_mom(x[:-2].reshape(6, -1)).reshape(2, 128)
    assert float(jnp.max(jnp.abs(agg - med))) < 50.0
    assert (np.asarray(tok) == 17).all()


def test_fused_tail_validates():
    x = _stack(jax.random.PRNGKey(0), 4, 2, 32)
    with pytest.raises(ValueError):
        aggregate_sample_pallas(x[0], interpret=True)  # not [m, B, V]
    with pytest.raises(ValueError):
        aggregate_sample_pallas(x, top_k=33, interpret=True)  # k > V
