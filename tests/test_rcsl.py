"""Integration tests for the RCSL algorithm (paper Section 3/4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rcsl as R


@pytest.fixture(scope="module")
def lin_shards():
    p = 10
    theta = R.paper_theta_star(p)
    shards = R.make_shards(
        jax.random.PRNGKey(0), N_per_machine=200, m_workers=20, p=p,
        theta_star=theta, model="linear",
    )
    return shards, theta


def _rmse(a, b):
    return float(jnp.sqrt(jnp.mean((a - b) ** 2)))


def test_rcsl_converges_clean(lin_shards):
    shards, theta = lin_shards
    est, traj = R.rcsl(
        R.LinearRegressionProblem(), shards, jax.random.PRNGKey(1),
        alpha=0.0, rounds=6,
    )
    # Improvement over the master-only initial estimator.
    assert _rmse(est, theta) < _rmse(traj[0], theta)
    assert _rmse(est, theta) < 0.05


@pytest.mark.parametrize("attack", ["gaussian", "omniscient", "bitflip"])
def test_rcsl_robust_to_attacks(lin_shards, attack):
    shards, theta = lin_shards
    est, _ = R.rcsl(
        R.LinearRegressionProblem(), shards, jax.random.PRNGKey(2),
        alpha=0.15, attack=attack, rounds=8,
    )
    assert _rmse(est, theta) < 0.08
    # Plain-mean aggregation is destroyed by the same attack.
    est_mean, _ = R.rcsl(
        R.LinearRegressionProblem(), shards, jax.random.PRNGKey(2),
        alpha=0.15, attack=attack, rounds=8, aggregator="mean",
    )
    if attack != "bitflip":  # bitflip is mild on the mean
        err_mean = _rmse(est_mean, theta)
        # NaN/inf counts as destroyed (omniscient 1e10-scaled attack diverges).
        assert (not np.isfinite(err_mean)) or err_mean > 5 * _rmse(est, theta)


def test_rcsl_beats_mom_rcsl(lin_shards):
    """Paper Tables 3-4: RMSE(RCSL-VRMOM) < RMSE(MOM-RCSL), averaged."""
    p = 10
    theta = R.paper_theta_star(p)
    errs_v, errs_m = [], []
    for rep in range(12):
        shards = R.make_shards(
            jax.random.PRNGKey(100 + rep), N_per_machine=200, m_workers=30,
            p=p, theta_star=theta, model="linear",
        )
        kv = jax.random.PRNGKey(rep)
        est_v, _ = R.rcsl(R.LinearRegressionProblem(), shards, kv,
                          alpha=0.1, attack="gaussian", rounds=6)
        est_m, _ = R.rcsl(R.LinearRegressionProblem(), shards, kv,
                          alpha=0.1, attack="gaussian", rounds=6,
                          aggregator="median")
        errs_v.append(_rmse(est_v, theta))
        errs_m.append(_rmse(est_m, theta))
    assert np.mean(errs_v) < np.mean(errs_m)


def test_rcsl_logistic_labelflip():
    p = 8
    theta = R.paper_theta_star(p)
    shards = R.make_shards(
        jax.random.PRNGKey(7), N_per_machine=400, m_workers=20, p=p,
        theta_star=theta, model="logistic",
    )
    est, traj = R.rcsl(
        R.LogisticRegressionProblem(), shards, jax.random.PRNGKey(8),
        alpha=0.1, labelflip=True, rounds=8,
    )
    assert _rmse(est, theta) < _rmse(traj[0], theta) + 1e-6
    assert _rmse(est, theta) < 0.15


def test_rcsl_generic_problem_matches_linear():
    p = 6
    theta = R.paper_theta_star(p)
    shards = R.make_shards(
        jax.random.PRNGKey(11), N_per_machine=300, m_workers=10, p=p,
        theta_star=theta, model="linear",
    )
    prob_g = R.GenericProblem(
        loss_fn=lambda th, x, y: (y - x @ th) ** 2, master_steps=400, lr=0.2,
    )
    est_g, _ = R.rcsl(prob_g, shards, jax.random.PRNGKey(12), rounds=5)
    est_c, _ = R.rcsl(R.LinearRegressionProblem(), shards,
                      jax.random.PRNGKey(12), rounds=5)
    np.testing.assert_allclose(np.asarray(est_g), np.asarray(est_c), atol=2e-2)
